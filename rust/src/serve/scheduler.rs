//! The shared worker fleet: a fixed-size thread pool executing
//! (session, band)-tagged jobs with per-band FIFO order and fair
//! round-robin draining.
//!
//! Every band of every session is a [`BandActor`]: a job queue plus the
//! band's state ([`crate::coordinator::router::BandWriter`] or
//! [`crate::denoise::sharded::BandScorer`]). An actor sits in the
//! pool's global ready queue **at most once** (the `scheduled` flag)
//! and is processed by **at most one worker at a time**, so jobs on one
//! band execute strictly in enqueue order — writes land before the
//! snapshot that must observe them — while different bands (of the same
//! or different sessions) run concurrently on however many workers the
//! pool owns.
//!
//! Fairness: a worker takes an actor, runs **one** job, and re-queues
//! the actor at the tail if more jobs remain. The ready queue therefore
//! round-robins across every (session, band) with pending work — a hot
//! camera flooding its own bands cannot starve the others; it only
//! lengthens its own turnaround.
//!
//! Thread count is fixed at pool construction: sessions spawn no
//! threads of their own (band renders run with `render_chunks = 1`), so
//! the whole fleet is bounded by `workers`, not by session count.

use crate::coordinator::router::{BandSnapshot, BandWriter};
use crate::denoise::sharded::{BandScorer, ScoreItem, ShardTally};
use crate::events::Event;
use crate::util::grid::Grid;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Band-local state a job operates on (boxed: actors are long-lived,
/// the enum is moved in and out of the actor on every job turn).
pub(crate) enum BandState {
    Writer(Box<BandWriter>),
    Scorer(Box<BandScorer>),
}

/// Reply to [`Job::Score`].
pub(crate) struct ScoreDone {
    pub scores: Vec<(u32, u32)>,
}

/// Reply to [`Job::Snapshot`].
pub(crate) struct SnapDone {
    pub band: usize,
    pub buf: Grid<f64>,
    pub rendered: bool,
    pub empty_static: bool,
}

/// Reply to [`Job::Close`].
pub(crate) struct CloseDone {
    pub band: usize,
    /// Events the band writer absorbed (0 for scorer bands).
    pub written: u64,
    /// The scorer band's tallies (None for writer bands).
    pub tally: Option<ShardTally>,
}

/// One queued unit of work, tagged by its (session, band) actor.
pub(crate) enum Job {
    /// Apply a write batch (sensor-coordinate events) to the band array.
    /// Fire-and-forget; counted against the session's in-flight bound.
    Write(Vec<Event>),
    /// Score a time-ordered item list causally and reply.
    Score { items: Vec<ScoreItem>, reply: SyncSender<ScoreDone> },
    /// Render (or certify unchanged) the band at `at_us` and reply with
    /// the recycled buffer — the dirty-band snapshot protocol, verbatim
    /// from the router.
    Snapshot {
        at_us: u64,
        buf: Grid<f64>,
        cache_valid: bool,
        band: usize,
        reply: SyncSender<SnapDone>,
    },
    /// Drop the band state (freeing its arrays), report the final
    /// counters, and acknowledge.
    Close { band: usize, reply: SyncSender<CloseDone> },
}

/// One (session, band) actor: a FIFO of jobs plus the band state.
pub(crate) struct BandActor {
    inner: Mutex<ActorInner>,
    /// The owning session's in-flight write-batch gauge (admission
    /// control reads it; workers decrement it as write jobs complete).
    inflight: Arc<AtomicUsize>,
    /// Fleet gauge of live band states (decremented by [`Job::Close`]).
    open_bands: Arc<AtomicUsize>,
}

struct ActorInner {
    jobs: VecDeque<Job>,
    /// True while the actor sits in the ready queue or on a worker.
    scheduled: bool,
    /// None after [`Job::Close`] ran (the band is freed).
    state: Option<BandState>,
}

struct ReadyQueue {
    ready: VecDeque<Arc<BandActor>>,
    /// Outstanding [`HoldGuard`]s: workers idle while > 0 (drain gate).
    holds: usize,
    shutdown: bool,
}

struct PoolShared {
    queue: Mutex<ReadyQueue>,
    cv: Condvar,
    jobs_executed: AtomicU64,
}

/// The fixed worker fleet.
pub(crate) struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

/// Pauses the worker fleet while alive (workers finish their current
/// job, then idle). Returned by `SessionManager::hold_workers`; dropping
/// it resumes draining. Used to stage deterministic backpressure and
/// for maintenance drains.
pub struct HoldGuard {
    shared: Arc<PoolShared>,
}

impl Drop for HoldGuard {
    fn drop(&mut self) {
        let mut q = self.shared.queue.lock().expect("pool lock");
        q.holds -= 1;
        if q.holds == 0 {
            self.shared.cv.notify_all();
        }
    }
}

impl WorkerPool {
    pub(crate) fn new(workers: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(ReadyQueue { ready: VecDeque::new(), holds: 0, shutdown: false }),
            cv: Condvar::new(),
            jobs_executed: AtomicU64::new(0),
        });
        let handles = (0..workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Self { shared, handles }
    }

    pub(crate) fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Register a new band actor with the fleet gauges.
    pub(crate) fn spawn_actor(
        &self,
        state: BandState,
        inflight: Arc<AtomicUsize>,
        open_bands: Arc<AtomicUsize>,
    ) -> Arc<BandActor> {
        open_bands.fetch_add(1, Ordering::SeqCst);
        Arc::new(BandActor {
            inner: Mutex::new(ActorInner {
                jobs: VecDeque::new(),
                scheduled: false,
                state: Some(state),
            }),
            inflight,
            open_bands,
        })
    }

    /// Enqueue `job` on `actor`'s FIFO; schedules the actor if idle.
    /// Never blocks on job execution — backpressure is the session
    /// layer's admission check against the in-flight gauge.
    pub(crate) fn enqueue(&self, actor: &Arc<BandActor>, job: Job) {
        if matches!(job, Job::Write(_)) {
            actor.inflight.fetch_add(1, Ordering::SeqCst);
        }
        let newly_scheduled = {
            let mut inner = actor.inner.lock().expect("actor lock");
            inner.jobs.push_back(job);
            if inner.scheduled {
                false
            } else {
                inner.scheduled = true;
                true
            }
        };
        if newly_scheduled {
            let mut q = self.shared.queue.lock().expect("pool lock");
            q.ready.push_back(actor.clone());
            self.shared.cv.notify_one();
        }
    }

    /// Jobs executed fleet-wide since construction.
    pub(crate) fn jobs_executed(&self) -> u64 {
        self.shared.jobs_executed.load(Ordering::Relaxed)
    }

    /// Actors currently waiting in the global ready queue.
    pub(crate) fn ready_depth(&self) -> usize {
        self.shared.queue.lock().expect("pool lock").ready.len()
    }

    /// Pause draining until the guard drops (see [`HoldGuard`]).
    pub(crate) fn hold(&self) -> HoldGuard {
        self.shared.queue.lock().expect("pool lock").holds += 1;
        HoldGuard { shared: self.shared.clone() }
    }

    /// Stop the fleet: workers drain every queued job, then exit.
    pub(crate) fn shutdown(mut self) {
        {
            let mut q = self.shared.queue.lock().expect("pool lock");
            q.shutdown = true;
            self.shared.cv.notify_all();
        }
        for h in self.handles.drain(..) {
            h.join().expect("join worker");
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        // Claim the next ready actor (or exit once shut down and dry).
        // A hold gates new claims but never blocks shutdown drain.
        let actor = {
            let mut q = shared.queue.lock().expect("pool lock");
            loop {
                let gated = q.holds > 0 && !q.shutdown;
                if !gated {
                    if let Some(a) = q.ready.pop_front() {
                        break a;
                    }
                    if q.shutdown {
                        return;
                    }
                }
                q = shared.cv.wait(q).expect("pool lock");
            }
        };
        // Take one job plus the band state out of the actor, so enqueues
        // from producer threads never block on job execution. The
        // `scheduled` flag guarantees this worker owns the actor alone.
        let (job, mut state) = {
            let mut inner = actor.inner.lock().expect("actor lock");
            let job = inner.jobs.pop_front().expect("scheduled actor has a job");
            (job, inner.state.take())
        };
        execute(job, &mut state, &actor);
        shared.jobs_executed.fetch_add(1, Ordering::Relaxed);
        // Put the state back; one job per turn, re-queue at the tail if
        // work remains (round-robin fairness across all bands).
        let requeue = {
            let mut inner = actor.inner.lock().expect("actor lock");
            inner.state = state;
            if inner.jobs.is_empty() {
                inner.scheduled = false;
                false
            } else {
                true
            }
        };
        if requeue {
            let mut q = shared.queue.lock().expect("pool lock");
            q.ready.push_back(actor.clone());
            shared.cv.notify_one();
        }
    }
}

/// Drop a band's state after a job panicked on it. The band is dead,
/// but the actor keeps draining: later jobs take the stateless paths
/// below (no-op + reply), so a waiting `snapshot`/`drain`/`close`
/// completes instead of wedging the whole session. This mirrors the
/// dedicated router's failure visibility (`expect("shard died")`) in
/// queue form — the panic message still lands on stderr via the
/// default hook.
fn poison(state: &mut Option<BandState>, actor: &BandActor) {
    if state.take().is_some() {
        actor.open_bands.fetch_sub(1, Ordering::SeqCst);
    }
}

fn execute(job: Job, state: &mut Option<BandState>, actor: &BandActor) {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    match job {
        Job::Write(mut batch) => {
            if let Some(BandState::Writer(w)) = state {
                if catch_unwind(AssertUnwindSafe(|| w.apply_batch(&mut batch))).is_err() {
                    poison(state, actor);
                }
            }
            actor.inflight.fetch_sub(1, Ordering::SeqCst);
        }
        Job::Score { items, reply } => {
            let mut scores = Vec::new();
            if let Some(BandState::Scorer(s)) = state {
                if catch_unwind(AssertUnwindSafe(|| s.process(&items, &mut scores))).is_err() {
                    poison(state, actor);
                }
            }
            let _ = reply.send(ScoreDone { scores });
        }
        Job::Snapshot { at_us, mut buf, cache_valid, band, reply } => {
            let mut out = BandSnapshot { rendered: false, empty_static: false };
            if let Some(BandState::Writer(w)) = state {
                let render = catch_unwind(AssertUnwindSafe(|| {
                    w.snapshot_into(&mut buf, at_us, cache_valid)
                }));
                match render {
                    Ok(o) => out = o,
                    Err(_) => poison(state, actor),
                }
            }
            let rendered = out.rendered;
            let empty_static = out.empty_static;
            let _ = reply.send(SnapDone { band, buf, rendered, empty_static });
        }
        Job::Close { band, reply } => {
            let (written, tally) = match state.take() {
                Some(BandState::Writer(w)) => {
                    let n = w.events_written();
                    // Dropping `w` here frees the band's arrays — the
                    // fleet gauge reflects it before the ack lands.
                    drop(w);
                    actor.open_bands.fetch_sub(1, Ordering::SeqCst);
                    (n, None)
                }
                Some(BandState::Scorer(s)) => {
                    let tally = s.tally().clone();
                    drop(s);
                    actor.open_bands.fetch_sub(1, Ordering::SeqCst);
                    (0, Some(tally))
                }
                None => (0, None),
            };
            let _ = reply.send(CloseDone { band, written, tally });
        }
    }
}
