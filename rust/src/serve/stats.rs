//! Fleet-wide and per-session serving statistics.
//!
//! [`SessionStats`] is the live, producer-side view of one session
//! (counters the session updates as it ingests — no worker round-trips
//! needed); [`SessionReport`] is the final accounting a `close`
//! returns, which additionally assembles a full
//! [`crate::coordinator::PipelineStats`] — per-band written counts and
//! denoise tallies included — so a serve session reports exactly the
//! shape a standalone `pipeline::run` does. [`ServeStats`] aggregates
//! the fleet: worker count, queue depths, executed jobs, rejections.

use crate::coordinator::PipelineStats;
use crate::events::Resolution;
use crate::util::stats::percentile;

/// Live statistics of one open session.
#[derive(Clone, Debug)]
pub struct SessionStats {
    /// The session's id (see `SessionId`).
    pub id: u64,
    /// Display label from the session config.
    pub name: String,
    pub res: Resolution,
    /// Events accepted by `ingest_batch` (rejected batches excluded).
    pub events_in: u64,
    /// Events routed to the write bands (post-STCF).
    pub events_routed: u64,
    pub events_dropped_by_stcf: u64,
    /// Window frames emitted by the session clock.
    pub frames_emitted: u64,
    /// Frame snapshots served (window frames + on-demand).
    pub snapshots_served: u64,
    /// Band renders avoided by the dirty-band protocol.
    pub bands_skipped_unchanged: u64,
    /// Write-batch jobs shipped to the band writers.
    pub batches_shipped: u64,
    /// Write batches queued or running on the fleet right now.
    pub queue_depth: usize,
    /// High-water mark of `queue_depth`.
    pub peak_queue_depth: usize,
    /// `ingest_batch` calls rejected by admission control.
    pub rejected_batches: u64,
    /// p50 of producer-side `ingest_batch` wall latency
    /// (**time-to-ACK**: staging + enqueue, *not* queue wait or band
    /// service — see `serve::obs` module docs), microseconds; 0 when no
    /// batch completed yet. This is the µs-backed successor of the old
    /// `batch_latency_p50_ms` field, same measurement.
    pub ingest_ack_p50_us: f64,
    /// p99 of producer-side `ingest_batch` wall latency, microseconds.
    pub ingest_ack_p99_us: f64,
    /// p50 of **end-to-end** batch latency (enqueue → band writer
    /// applied the batch, i.e. queue wait + write service),
    /// microseconds. Bucket-quantized: read from the session's
    /// `batch_e2e_us` log2 histogram, so values are bucket upper
    /// bounds; 0 under `telemetry-off`.
    pub batch_e2e_p50_us: f64,
    /// p99 of end-to-end batch latency, microseconds (see
    /// [`SessionStats::batch_e2e_p50_us`]).
    pub batch_e2e_p99_us: f64,
    /// Approximate resident bytes of the session's band states (writer
    /// arrays + scorer surfaces), maintained by the fleet workers as
    /// jobs complete. Activity-proportional under lazy materialization:
    /// cold bands contribute a small constant, and an idle session's
    /// bytes decay as its bands expire past the memory horizon and
    /// demote.
    pub resident_bytes: usize,
}

impl SessionStats {
    /// The pre-µs-unification name and unit of
    /// [`SessionStats::ingest_ack_p50_us`].
    #[deprecated(note = "units unified to µs repo-wide; read ingest_ack_p50_us")]
    pub fn batch_latency_p50_ms(&self) -> f64 {
        self.ingest_ack_p50_us / 1e3
    }

    /// The pre-µs-unification name and unit of
    /// [`SessionStats::ingest_ack_p99_us`].
    #[deprecated(note = "units unified to µs repo-wide; read ingest_ack_p99_us")]
    pub fn batch_latency_p99_ms(&self) -> f64 {
        self.ingest_ack_p99_us / 1e3
    }
}

/// Final accounting of one closed session.
#[derive(Clone, Debug)]
pub struct SessionReport {
    /// The closing snapshot of the live counters.
    pub stats: SessionStats,
    /// The standalone-pipeline-shaped totals: stage wall times, per-band
    /// written counts, denoise tallies, router counters, throughput.
    pub pipeline: PipelineStats,
}

/// Fleet-wide aggregate over the shared worker pool and every open
/// session.
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// Fixed worker-thread count (the whole fleet's parallelism budget —
    /// independent of how many sessions are open).
    pub workers: usize,
    pub open_sessions: usize,
    /// Live band states (writer + scorer bands across all sessions);
    /// drops as sessions close.
    pub open_bands: usize,
    /// Jobs executed fleet-wide since the manager was built.
    pub jobs_executed: u64,
    /// Band actors waiting in the global ready queue right now.
    pub ready_depth: usize,
    /// Rejected `ingest_batch` calls, fleet-wide (closed sessions
    /// included).
    pub rejected_batches: u64,
    /// Events accepted fleet-wide (closed sessions included).
    pub events_in: u64,
    /// Approximate resident bytes across every open session's band
    /// states (the sum of the per-session gauges) — the number the
    /// idle-fleet `bench_serve` sweep reports per session.
    pub resident_bytes: usize,
    /// Per-open-session live stats.
    pub sessions: Vec<SessionStats>,
    /// Network front-door counters (all zero when the fleet is driven
    /// in-process; filled by `serve::net::NetServer::stats`).
    pub net: NetStats,
    /// Supervision counters: quarantines, respawns, degradation tiers,
    /// checkpoints (see [`crate::serve::supervise`]).
    pub supervisor: SupervisorStats,
}

/// Counters of the fleet supervision layer (`serve::supervise`): panic
/// isolation, worker respawns, overload degradation tiers, and
/// checkpoint/restore traffic. The chaos harness (`tests/fleet_chaos.rs`)
/// asserts every injected scheduler fault lands in exactly one of these
/// buckets — nothing a faulty job can do goes unaccounted.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SupervisorStats {
    /// Sessions quarantined after a job panic (the panic was caught at
    /// the supervision boundary; the worker and the rest of the fleet
    /// kept running).
    pub quarantines: u64,
    /// Jobs whose body panicked (caught by `catch_boundary`; each one
    /// quarantines its session, never poisons the pool).
    pub worker_panics: u64,
    /// Worker threads respawned by the supervisor after a death.
    pub worker_respawns: u64,
    /// True once the respawn budget was exhausted inside its window —
    /// the fleet keeps serving on the surviving workers but is flagged.
    pub fleet_degraded: bool,
    /// Snapshot jobs that completed after their soft deadline.
    pub deadline_misses: u64,
    /// Degradation tier 1: provably event-free cold bands served as
    /// zero fill instead of being scheduled (lossless).
    pub deferred_cold_snapshots: u64,
    /// Degradation tier 2: dirty bands served from their last rendered
    /// cache, with the staleness marker set on the FRAME.
    pub stale_frames_served: u64,
    /// Degradation tier 3: new sessions shed at open under overload.
    pub sessions_shed_overloaded: u64,
    /// Checkpoints encoded (`SessionManager::checkpoint`).
    pub checkpoints_taken: u64,
    /// Restores refused by the CRC/fingerprint guard — corruption was
    /// *detected*, never silently applied.
    pub checkpoint_corruptions_detected: u64,
    /// Restores applied (in place or migrated).
    pub restores_completed: u64,
    /// Faults injected by an armed [`crate::serve::supervise::SchedFaultPlan`]:
    /// job panics.
    pub injected_panics: u64,
    /// Injected job stalls (deadline pressure).
    pub injected_stalls: u64,
    /// Injected checkpoint corruptions (must all be *detected*).
    pub injected_checkpoint_corruptions: u64,
}

/// Counters of the TCP front door (`serve::net`): every accepted,
/// shed, rejected or faulted interaction, by type. The chaos harness
/// (`tests/net_chaos.rs`) asserts each injected fault lands in exactly
/// one of these buckets — nothing a client can send is unaccounted.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted by the listener.
    pub connections_accepted: u64,
    /// Connections shed at accept time (listener at its connection cap)
    /// — whole-connection degradation before any admitted session slows.
    pub connections_shed: u64,
    /// HELLOs refused by session admission (`TooManySessions`, …).
    pub hellos_rejected: u64,
    /// Sessions opened over the wire.
    pub sessions_opened: u64,
    /// BATCH frames ingested and acknowledged.
    pub batches_acked: u64,
    /// Events ingested over the wire (post-decode, pre-STCF).
    pub events_ingested: u64,
    /// Window/snapshot FRAME replies sent.
    pub frames_sent: u64,
    /// NACK frames sent, all causes.
    pub nacks_sent: u64,
    /// Frames refused for a malformed or oversized header.
    pub bad_frames: u64,
    /// Frames refused for a payload checksum mismatch.
    pub checksum_errors: u64,
    /// BATCH payloads refused with a typed `AerError`.
    pub decode_errors: u64,
    /// Protocol-order violations (BATCH before HELLO, seq gaps, …).
    pub protocol_errors: u64,
    /// Duplicate BATCH frames (seq already acknowledged) — detected,
    /// NACKed, and *not* re-ingested.
    pub duplicate_batches: u64,
    /// Backpressure NACKs (retry-after hint attached).
    pub backpressure_nacks: u64,
    /// Connections dropped for missing a read/idle deadline.
    pub deadline_disconnects: u64,
    /// Connections dropped after exhausting the decode-error budget.
    pub budget_disconnects: u64,
    /// Peers that vanished mid-conversation (EOF / reset).
    pub abrupt_disconnects: u64,
    /// Faulted or vanished sessions that were drained-then-closed (never
    /// dropped): their acked events all reached the band writers.
    pub sessions_drained_on_error: u64,
    /// Drained sessions whose final accounting did not balance
    /// (events_in ≠ written + dropped-by-STCF). Always 0; a nonzero
    /// value means an acked batch was lost.
    pub drain_accounting_mismatches: u64,
    /// Connection-handler threads that panicked (always 0; asserted by
    /// the chaos harness).
    pub handler_panics: u64,
    /// Sessions ended by a clean BYE handshake.
    pub byes_completed: u64,
}

/// (p50, p99) of a latency sample set, seconds in → **microseconds**
/// out (the repo's one duration unit); zeros when empty.
pub(crate) fn latency_percentiles_us(samples_s: &[f64]) -> (f64, f64) {
    if samples_s.is_empty() {
        return (0.0, 0.0);
    }
    (percentile(samples_s, 50.0) * 1e6, percentile(samples_s, 99.0) * 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles_handle_empty_and_scale_to_us() {
        assert_eq!(latency_percentiles_us(&[]), (0.0, 0.0));
        let (p50, p99) = latency_percentiles_us(&[0.001, 0.002, 0.003]);
        assert!((p50 - 2_000.0).abs() < 1e-6, "p50={p50}");
        assert!(p99 > 2_900.0 && p99 <= 3_000.0, "p99={p99}");
    }

    #[test]
    fn deprecated_ms_accessors_rescale_the_us_fields() {
        let s = SessionStats {
            id: 0,
            name: String::new(),
            res: crate::events::Resolution { width: 1, height: 1 },
            events_in: 0,
            events_routed: 0,
            events_dropped_by_stcf: 0,
            frames_emitted: 0,
            snapshots_served: 0,
            bands_skipped_unchanged: 0,
            batches_shipped: 0,
            queue_depth: 0,
            peak_queue_depth: 0,
            rejected_batches: 0,
            ingest_ack_p50_us: 1_500.0,
            ingest_ack_p99_us: 4_000.0,
            batch_e2e_p50_us: 0.0,
            batch_e2e_p99_us: 0.0,
            resident_bytes: 0,
        };
        #[allow(deprecated)]
        let (p50_ms, p99_ms) = (s.batch_latency_p50_ms(), s.batch_latency_p99_ms());
        assert!((p50_ms - 1.5).abs() < 1e-12);
        assert!((p99_ms - 4.0).abs() < 1e-12);
    }
}
