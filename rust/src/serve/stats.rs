//! Fleet-wide and per-session serving statistics.
//!
//! [`SessionStats`] is the live, producer-side view of one session
//! (counters the session updates as it ingests — no worker round-trips
//! needed); [`SessionReport`] is the final accounting a `close`
//! returns, which additionally assembles a full
//! [`crate::coordinator::PipelineStats`] — per-band written counts and
//! denoise tallies included — so a serve session reports exactly the
//! shape a standalone `pipeline::run` does. [`ServeStats`] aggregates
//! the fleet: worker count, queue depths, executed jobs, rejections.

use crate::coordinator::PipelineStats;
use crate::events::Resolution;
use crate::util::stats::percentile;

/// Live statistics of one open session.
#[derive(Clone, Debug)]
pub struct SessionStats {
    /// The session's id (see `SessionId`).
    pub id: u64,
    /// Display label from the session config.
    pub name: String,
    pub res: Resolution,
    /// Events accepted by `ingest_batch` (rejected batches excluded).
    pub events_in: u64,
    /// Events routed to the write bands (post-STCF).
    pub events_routed: u64,
    pub events_dropped_by_stcf: u64,
    /// Window frames emitted by the session clock.
    pub frames_emitted: u64,
    /// Frame snapshots served (window frames + on-demand).
    pub snapshots_served: u64,
    /// Band renders avoided by the dirty-band protocol.
    pub bands_skipped_unchanged: u64,
    /// Write-batch jobs shipped to the band writers.
    pub batches_shipped: u64,
    /// Write batches queued or running on the fleet right now.
    pub queue_depth: usize,
    /// High-water mark of `queue_depth`.
    pub peak_queue_depth: usize,
    /// `ingest_batch` calls rejected by admission control.
    pub rejected_batches: u64,
    /// p50 of per-`ingest_batch` wall latency, milliseconds (0 when no
    /// batch completed yet).
    pub batch_latency_p50_ms: f64,
    /// p99 of per-`ingest_batch` wall latency, milliseconds.
    pub batch_latency_p99_ms: f64,
    /// Approximate resident bytes of the session's band states (writer
    /// arrays + scorer surfaces), maintained by the fleet workers as
    /// jobs complete. Activity-proportional under lazy materialization:
    /// cold bands contribute a small constant, and an idle session's
    /// bytes decay as its bands expire past the memory horizon and
    /// demote.
    pub resident_bytes: usize,
}

/// Final accounting of one closed session.
#[derive(Clone, Debug)]
pub struct SessionReport {
    /// The closing snapshot of the live counters.
    pub stats: SessionStats,
    /// The standalone-pipeline-shaped totals: stage wall times, per-band
    /// written counts, denoise tallies, router counters, throughput.
    pub pipeline: PipelineStats,
}

/// Fleet-wide aggregate over the shared worker pool and every open
/// session.
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// Fixed worker-thread count (the whole fleet's parallelism budget —
    /// independent of how many sessions are open).
    pub workers: usize,
    pub open_sessions: usize,
    /// Live band states (writer + scorer bands across all sessions);
    /// drops as sessions close.
    pub open_bands: usize,
    /// Jobs executed fleet-wide since the manager was built.
    pub jobs_executed: u64,
    /// Band actors waiting in the global ready queue right now.
    pub ready_depth: usize,
    /// Rejected `ingest_batch` calls, fleet-wide (closed sessions
    /// included).
    pub rejected_batches: u64,
    /// Events accepted fleet-wide (closed sessions included).
    pub events_in: u64,
    /// Approximate resident bytes across every open session's band
    /// states (the sum of the per-session gauges) — the number the
    /// idle-fleet `bench_serve` sweep reports per session.
    pub resident_bytes: usize,
    /// Per-open-session live stats.
    pub sessions: Vec<SessionStats>,
    /// Network front-door counters (all zero when the fleet is driven
    /// in-process; filled by `serve::net::NetServer::stats`).
    pub net: NetStats,
    /// Supervision counters: quarantines, respawns, degradation tiers,
    /// checkpoints (see [`crate::serve::supervise`]).
    pub supervisor: SupervisorStats,
}

/// Counters of the fleet supervision layer (`serve::supervise`): panic
/// isolation, worker respawns, overload degradation tiers, and
/// checkpoint/restore traffic. The chaos harness (`tests/fleet_chaos.rs`)
/// asserts every injected scheduler fault lands in exactly one of these
/// buckets — nothing a faulty job can do goes unaccounted.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SupervisorStats {
    /// Sessions quarantined after a job panic (the panic was caught at
    /// the supervision boundary; the worker and the rest of the fleet
    /// kept running).
    pub quarantines: u64,
    /// Jobs whose body panicked (caught by `catch_boundary`; each one
    /// quarantines its session, never poisons the pool).
    pub worker_panics: u64,
    /// Worker threads respawned by the supervisor after a death.
    pub worker_respawns: u64,
    /// True once the respawn budget was exhausted inside its window —
    /// the fleet keeps serving on the surviving workers but is flagged.
    pub fleet_degraded: bool,
    /// Snapshot jobs that completed after their soft deadline.
    pub deadline_misses: u64,
    /// Degradation tier 1: provably event-free cold bands served as
    /// zero fill instead of being scheduled (lossless).
    pub deferred_cold_snapshots: u64,
    /// Degradation tier 2: dirty bands served from their last rendered
    /// cache, with the staleness marker set on the FRAME.
    pub stale_frames_served: u64,
    /// Degradation tier 3: new sessions shed at open under overload.
    pub sessions_shed_overloaded: u64,
    /// Checkpoints encoded (`SessionManager::checkpoint`).
    pub checkpoints_taken: u64,
    /// Restores refused by the CRC/fingerprint guard — corruption was
    /// *detected*, never silently applied.
    pub checkpoint_corruptions_detected: u64,
    /// Restores applied (in place or migrated).
    pub restores_completed: u64,
    /// Faults injected by an armed [`crate::serve::supervise::SchedFaultPlan`]:
    /// job panics.
    pub injected_panics: u64,
    /// Injected job stalls (deadline pressure).
    pub injected_stalls: u64,
    /// Injected checkpoint corruptions (must all be *detected*).
    pub injected_checkpoint_corruptions: u64,
}

/// Counters of the TCP front door (`serve::net`): every accepted,
/// shed, rejected or faulted interaction, by type. The chaos harness
/// (`tests/net_chaos.rs`) asserts each injected fault lands in exactly
/// one of these buckets — nothing a client can send is unaccounted.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted by the listener.
    pub connections_accepted: u64,
    /// Connections shed at accept time (listener at its connection cap)
    /// — whole-connection degradation before any admitted session slows.
    pub connections_shed: u64,
    /// HELLOs refused by session admission (`TooManySessions`, …).
    pub hellos_rejected: u64,
    /// Sessions opened over the wire.
    pub sessions_opened: u64,
    /// BATCH frames ingested and acknowledged.
    pub batches_acked: u64,
    /// Events ingested over the wire (post-decode, pre-STCF).
    pub events_ingested: u64,
    /// Window/snapshot FRAME replies sent.
    pub frames_sent: u64,
    /// NACK frames sent, all causes.
    pub nacks_sent: u64,
    /// Frames refused for a malformed or oversized header.
    pub bad_frames: u64,
    /// Frames refused for a payload checksum mismatch.
    pub checksum_errors: u64,
    /// BATCH payloads refused with a typed `AerError`.
    pub decode_errors: u64,
    /// Protocol-order violations (BATCH before HELLO, seq gaps, …).
    pub protocol_errors: u64,
    /// Duplicate BATCH frames (seq already acknowledged) — detected,
    /// NACKed, and *not* re-ingested.
    pub duplicate_batches: u64,
    /// Backpressure NACKs (retry-after hint attached).
    pub backpressure_nacks: u64,
    /// Connections dropped for missing a read/idle deadline.
    pub deadline_disconnects: u64,
    /// Connections dropped after exhausting the decode-error budget.
    pub budget_disconnects: u64,
    /// Peers that vanished mid-conversation (EOF / reset).
    pub abrupt_disconnects: u64,
    /// Faulted or vanished sessions that were drained-then-closed (never
    /// dropped): their acked events all reached the band writers.
    pub sessions_drained_on_error: u64,
    /// Drained sessions whose final accounting did not balance
    /// (events_in ≠ written + dropped-by-STCF). Always 0; a nonzero
    /// value means an acked batch was lost.
    pub drain_accounting_mismatches: u64,
    /// Connection-handler threads that panicked (always 0; asserted by
    /// the chaos harness).
    pub handler_panics: u64,
    /// Sessions ended by a clean BYE handshake.
    pub byes_completed: u64,
}

/// (p50, p99) of a latency sample set in milliseconds; zeros when empty.
pub(crate) fn latency_percentiles_ms(samples_s: &[f64]) -> (f64, f64) {
    if samples_s.is_empty() {
        return (0.0, 0.0);
    }
    (percentile(samples_s, 50.0) * 1e3, percentile(samples_s, 99.0) * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles_handle_empty_and_scale_to_ms() {
        assert_eq!(latency_percentiles_ms(&[]), (0.0, 0.0));
        let (p50, p99) = latency_percentiles_ms(&[0.001, 0.002, 0.003]);
        assert!((p50 - 2.0).abs() < 1e-9, "p50={p50}");
        assert!(p99 > 2.9 && p99 <= 3.0, "p99={p99}");
    }
}
