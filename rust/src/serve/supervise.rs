//! Fleet supervision: panic isolation, session quarantine,
//! checkpoint/restore, and deadline-aware overload degradation.
//!
//! The serve fleet runs arbitrary session workloads on shared worker
//! threads; this module is the blast-radius containment around them.
//! Four mechanisms, each with its own typed accounting in
//! [`crate::serve::SupervisorStats`]:
//!
//! 1. **Panic isolation** — every scheduler job body runs under
//!    [`crate::util::sync::catch_boundary`]. A panic quarantines *that
//!    session* (a typed [`SessionFault`] lands on its [`FaultBoard`])
//!    instead of poisoning the pool; the worker thread survives, and if
//!    it ever does die the `util::actor` supervisor respawns it under a
//!    [`crate::util::actor::RestartBudget`].
//! 2. **Checkpoint/restore** — [`encode_checkpoint`] /
//!    [`decode_checkpoint`] serialize per-band session state (writer
//!    stamps + scorer backend stamps + tallies) into a compact,
//!    versioned, CRC-guarded blob. Stamps replay through the
//!    position-stable mismatch assignment
//!    ([`crate::isc::param_index_at`]), so a restored band renders
//!    bit-for-bit identically to one that never crashed.
//! 3. **Deadline-aware degradation** — [`SupervisorConfig`] maps a
//!    fleet [`pressure`] signal (queue depth × resident footprint) to a
//!    [`DegradeTier`]: defer provably event-free cold-band renders,
//!    then serve stale dirty-band caches (marked on the FRAME wire),
//!    then shed new sessions.
//! 4. **Fault injection** — [`SchedFaultPlan`] extends the seeded
//!    injector pattern of [`crate::serve::net::faults`] to
//!    scheduler-level fault points (panic / stall / checkpoint
//!    corruption), driving `tests/fleet_chaos.rs`.

use crate::coordinator::PipelineConfig;
use crate::denoise::ShardTally;
use crate::events::Resolution;
use crate::serve::net::frame::crc32;
use crate::serve::obs::FlightSample;
use crate::serve::stats::SupervisorStats;
use crate::util::rng::Pcg64;
use crate::util::sync::{Arc, AtomicU64, Mutex, Ordering};
use crate::util::telemetry::{Counter, Registry};

pub use crate::util::actor::SupervisionConfig;

// ---------------------------------------------------------------------------
// Quarantine: typed session faults
// ---------------------------------------------------------------------------

/// Which scheduler job kind a fault occurred in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultJobKind {
    /// A write batch headed for a band writer.
    Write,
    /// A shard-scoring job on a scorer band.
    Score,
    /// An on-demand or window frame render.
    Snapshot,
    /// Final band close/accounting.
    Close,
    /// Band state export for a checkpoint.
    Checkpoint,
    /// Band state install during a restore.
    Restore,
}

impl FaultJobKind {
    /// Stable lowercase label (used in fault details and NACK reasons).
    pub fn name(self) -> &'static str {
        match self {
            FaultJobKind::Write => "write",
            FaultJobKind::Score => "score",
            FaultJobKind::Snapshot => "snapshot",
            FaultJobKind::Close => "close",
            FaultJobKind::Checkpoint => "checkpoint",
            FaultJobKind::Restore => "restore",
        }
    }
}

/// One caught job panic, attributed to the session that owned the job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SessionFault {
    /// Band index the job was bound to.
    pub band: u16,
    /// Job kind that panicked.
    pub job: FaultJobKind,
    /// Panic payload summary (from `catch_boundary`).
    pub detail: String,
    /// The faulting band's flight-recorder tail at quarantine time —
    /// the last completed jobs (oldest first, the panicking job
    /// excluded since it never completed), each with queue-wait and
    /// service time, so a panic is diagnosable post-mortem. Empty under
    /// `telemetry-off` (the recorder compiles out) and for faults filed
    /// outside the scheduler.
    pub recent: Vec<FlightSample>,
}

impl std::fmt::Display for SessionFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} job panicked on band {}: {}", self.job.name(), self.band, self.detail)
    }
}

/// Per-session quarantine flag plus the faults that raised it.
///
/// Workers [`file`](FaultBoard::file) faults as they catch panics; the
/// session front door checks [`is_quarantined`](FaultBoard::is_quarantined)
/// on every ingest/snapshot and refuses with
/// `Reject::Quarantined` until a restore [`clear`](FaultBoard::clear)s
/// the board. The count is an atomic so the hot ingest path never takes
/// the fault-list lock.
#[derive(Debug, Default)]
pub struct FaultBoard {
    count: AtomicU64,
    faults: Mutex<Vec<SessionFault>>,
}

impl FaultBoard {
    /// Empty board (healthy session).
    pub fn new() -> Self {
        FaultBoard { count: AtomicU64::new(0), faults: Mutex::new(Vec::new()) }
    }

    /// Record a fault and quarantine the session. Returns the number of
    /// faults filed *before* this one (0 ⇔ this fault is the quarantine
    /// transition), so callers can count sessions rather than faults.
    pub fn file(&self, fault: SessionFault) -> u64 {
        self.faults.lock().expect("fault board lock").push(fault);
        self.count.fetch_add(1, Ordering::AcqRel)
    }

    /// Faults filed since the last [`clear`](FaultBoard::clear).
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Acquire)
    }

    /// True once any fault is filed.
    pub fn is_quarantined(&self) -> bool {
        self.count() > 0
    }

    /// Snapshot the filed faults (most recent last).
    pub fn faults(&self) -> Vec<SessionFault> {
        self.faults.lock().expect("fault board lock").clone()
    }

    /// Lift the quarantine (a successful restore replaces the state the
    /// faults referred to).
    pub fn clear(&self) {
        self.faults.lock().expect("fault board lock").clear();
        self.count.store(0, Ordering::Release);
    }
}

// ---------------------------------------------------------------------------
// Scheduler-level fault injection (chaos harness)
// ---------------------------------------------------------------------------

/// Scheduler fault classes the chaos harness can inject. Mirrors the
/// wire-level [`crate::serve::net::faults::FaultKind`] pattern: each
/// kind owns a PCG stream so plans are independent *and* reproducible
/// per `(seed, kind)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedFaultKind {
    /// Panic inside a job body (must quarantine, never poison).
    JobPanic,
    /// Stall a job past the soft deadline (must count a miss, not hang
    /// the fleet).
    JobStall,
    /// Flip one bit of an encoded checkpoint (must be *detected* by the
    /// CRC guard, never silently restored).
    CheckpointCorrupt,
}

impl SchedFaultKind {
    /// All injectable kinds, for exhaustive chaos sweeps.
    pub const ALL: [SchedFaultKind; 3] =
        [SchedFaultKind::JobPanic, SchedFaultKind::JobStall, SchedFaultKind::CheckpointCorrupt];

    /// Dedicated PCG stream per kind (0xfb.. block; the net injector
    /// owns 0xfa..) so per-kind plans never correlate.
    pub fn stream_key(self) -> u64 {
        match self {
            SchedFaultKind::JobPanic => 0xfb01,
            SchedFaultKind::JobStall => 0xfb02,
            SchedFaultKind::CheckpointCorrupt => 0xfb03,
        }
    }
}

/// A concrete, seed-derived plan for one injected fault: *which* job
/// ordinal it fires on and how (reproducible from `(kind, seed)` — the
/// chaos test prints the seed so any failure replays exactly).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedFaultPlan {
    /// Fault class.
    pub kind: SchedFaultKind,
    /// 1-based job ordinal (per session) the fault fires on.
    pub fire_on_job: u64,
    /// Stall length for [`SchedFaultKind::JobStall`], milliseconds.
    pub stall_ms: u64,
    /// Salt for the corruption bit position
    /// ([`SchedFaultKind::CheckpointCorrupt`]).
    pub corrupt_salt: u64,
}

impl SchedFaultPlan {
    /// Derive a plan from `(kind, seed)` on the kind's own PCG stream.
    pub fn from_seed(kind: SchedFaultKind, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, kind.stream_key());
        SchedFaultPlan {
            kind,
            fire_on_job: rng.range_u64(1, 5),
            stall_ms: rng.range_u64(2, 15),
            corrupt_salt: rng.next_u64(),
        }
    }
}

/// An installed fault plan, armed on one session. Fires **at most
/// once**; every firing is counted in [`SupervisorCounters`] before the
/// fault manifests, so the chaos harness can equate injected count with
/// observed typed outcomes.
#[derive(Debug)]
pub struct ArmedFault {
    plan: SchedFaultPlan,
    jobs_seen: AtomicU64,
    fired: AtomicU64,
}

impl ArmedFault {
    /// Arm a plan.
    pub fn new(plan: SchedFaultPlan) -> Self {
        ArmedFault { plan, jobs_seen: AtomicU64::new(0), fired: AtomicU64::new(0) }
    }

    /// The armed plan.
    pub fn plan(&self) -> SchedFaultPlan {
        self.plan
    }

    /// True once the fault has manifested.
    pub fn has_fired(&self) -> bool {
        self.fired.load(Ordering::Acquire) != 0
    }

    /// Scheduler hook, called before each job body **inside** the
    /// supervision boundary. [`SchedFaultKind::JobPanic`] plans panic
    /// here on purpose — this is the one sanctioned panic site on the
    /// worker path, which is why the `panic-boundary` lint bans `panic!`
    /// from the scheduler job bodies themselves.
    pub fn before_job(&self, counters: &SupervisorCounters) {
        if self.plan.kind == SchedFaultKind::CheckpointCorrupt {
            return;
        }
        let n = self.jobs_seen.fetch_add(1, Ordering::AcqRel) + 1;
        if n != self.plan.fire_on_job || self.fired.swap(1, Ordering::AcqRel) != 0 {
            return;
        }
        match self.plan.kind {
            SchedFaultKind::JobPanic => {
                counters.injected_panics.inc();
                panic!("injected fault: job panic on job #{n}");
            }
            SchedFaultKind::JobStall => {
                counters.injected_stalls.inc();
                std::thread::sleep(std::time::Duration::from_millis(self.plan.stall_ms));
            }
            SchedFaultKind::CheckpointCorrupt => {}
        }
    }

    /// Checkpoint hook: flip one seeded bit of `bytes`. Returns whether
    /// a corruption was applied (at most once per armed fault). The
    /// decoder's CRC guard must turn every applied corruption into a
    /// typed [`CheckpointError::CrcMismatch`].
    pub fn corrupt_checkpoint(&self, bytes: &mut [u8], counters: &SupervisorCounters) -> bool {
        if self.plan.kind != SchedFaultKind::CheckpointCorrupt || bytes.is_empty() {
            return false;
        }
        if self.fired.swap(1, Ordering::AcqRel) != 0 {
            return false;
        }
        let mut rng = Pcg64::with_stream(self.plan.corrupt_salt, self.plan.kind.stream_key());
        let i = rng.below(bytes.len() as u64) as usize;
        let bit = rng.below(8) as u32;
        bytes[i] ^= 1u8 << bit;
        counters.injected_checkpoint_corruptions.inc();
        true
    }
}

// ---------------------------------------------------------------------------
// Supervision counters and config
// ---------------------------------------------------------------------------

/// Shared atomic counters behind [`SupervisorStats`]. One instance per
/// [`crate::serve::SessionManager`], updated lock-free from workers and
/// the session front door. Since the telemetry migration these are
/// [`crate::util::telemetry::Counter`] handles — the *same* counters a
/// scrape renders — so supervision accounting and the observability
/// plane can never disagree.
#[derive(Debug)]
pub struct SupervisorCounters {
    pub(crate) quarantines: Arc<Counter>,
    pub(crate) job_panics: Arc<Counter>,
    pub(crate) deadline_misses: Arc<Counter>,
    pub(crate) deferred_cold_snapshots: Arc<Counter>,
    pub(crate) stale_frames_served: Arc<Counter>,
    pub(crate) sessions_shed_overloaded: Arc<Counter>,
    pub(crate) checkpoints_taken: Arc<Counter>,
    pub(crate) checkpoint_corruptions_detected: Arc<Counter>,
    pub(crate) restores_completed: Arc<Counter>,
    pub(crate) injected_panics: Arc<Counter>,
    pub(crate) injected_stalls: Arc<Counter>,
    pub(crate) injected_checkpoint_corruptions: Arc<Counter>,
}

impl SupervisorCounters {
    /// All-zero counters registered in `reg` under their exported
    /// names, so [`crate::util::telemetry::Registry::render`] covers
    /// supervision for free.
    pub fn registered(reg: &Registry) -> Self {
        SupervisorCounters {
            quarantines: reg.counter("quarantines_total"),
            job_panics: reg.counter("job_panics_total"),
            deadline_misses: reg.counter("deadline_misses_total"),
            deferred_cold_snapshots: reg.counter("deferred_cold_snapshots_total"),
            stale_frames_served: reg.counter("stale_frames_served_total"),
            sessions_shed_overloaded: reg.counter("sessions_shed_overloaded_total"),
            checkpoints_taken: reg.counter("checkpoints_taken_total"),
            checkpoint_corruptions_detected: reg.counter("checkpoint_corruptions_detected_total"),
            restores_completed: reg.counter("restores_completed_total"),
            injected_panics: reg.counter("injected_panics_total"),
            injected_stalls: reg.counter("injected_stalls_total"),
            injected_checkpoint_corruptions: reg
                .counter("injected_checkpoint_corruptions_total"),
        }
    }

    /// All-zero counters bound to no scrape surface (tests and
    /// standalone tools; the registry the handles came from is
    /// dropped — counters keep working, they just aren't rendered).
    pub fn new() -> Self {
        Self::registered(&Registry::new())
    }

    /// Materialize the stats struct, merging in the pool-owned numbers.
    /// `escaped_panics` counts panics that got past the job-body
    /// boundary to the worker loop (scheduler bugs — normally 0); the
    /// job-body catches themselves are tracked here and summed in.
    pub fn snapshot(
        &self,
        escaped_panics: u64,
        worker_respawns: u64,
        fleet_degraded: bool,
    ) -> SupervisorStats {
        SupervisorStats {
            quarantines: self.quarantines.get(),
            worker_panics: escaped_panics + self.job_panics.get(),
            worker_respawns,
            fleet_degraded,
            deadline_misses: self.deadline_misses.get(),
            deferred_cold_snapshots: self.deferred_cold_snapshots.get(),
            stale_frames_served: self.stale_frames_served.get(),
            sessions_shed_overloaded: self.sessions_shed_overloaded.get(),
            checkpoints_taken: self.checkpoints_taken.get(),
            checkpoint_corruptions_detected: self.checkpoint_corruptions_detected.get(),
            restores_completed: self.restores_completed.get(),
            injected_panics: self.injected_panics.get(),
            injected_stalls: self.injected_stalls.get(),
            injected_checkpoint_corruptions: self.injected_checkpoint_corruptions.get(),
        }
    }
}

impl Default for SupervisorCounters {
    fn default() -> Self {
        Self::new()
    }
}

/// Overload tiers, in escalation order. Each tier includes every tier
/// below it (ordering is meaningful: `tier >= ServeStale` ⇒ stale
/// service is permitted).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DegradeTier {
    /// No degradation: every snapshot renders exactly.
    Nominal,
    /// Defer cold-band renders: provably event-free bands are served as
    /// zero fill without scheduling a job (lossless — an event-free
    /// band renders to zeros anyway).
    DeferCold,
    /// Serve dirty bands from their last rendered cache, marking the
    /// FRAME stale instead of queueing renders the fleet can't absorb.
    ServeStale,
    /// Shed new sessions at open (`Reject::Overloaded`).
    Shed,
}

/// Fleet supervision policy: worker respawn budget, snapshot soft
/// deadline, and the pressure thresholds of each [`DegradeTier`].
///
/// Defaults never degrade (`u64::MAX` thresholds) so existing exactness
/// tests and benches are unaffected unless a deployment opts in.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// Worker respawn budget (see [`SupervisionConfig`]).
    pub supervision: SupervisionConfig,
    /// Soft per-snapshot deadline, µs; jobs finishing later count a
    /// [`SupervisorStats::deadline_misses`]. Never aborts work.
    pub snapshot_deadline_us: u64,
    /// Pressure at or above which cold-band renders are deferred.
    pub defer_cold_pressure: u64,
    /// Pressure at or above which dirty bands serve stale caches.
    pub serve_stale_pressure: u64,
    /// Pressure at or above which new sessions are shed.
    pub shed_pressure: u64,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            supervision: SupervisionConfig::default(),
            snapshot_deadline_us: 5_000_000,
            defer_cold_pressure: u64::MAX,
            serve_stale_pressure: u64::MAX,
            shed_pressure: u64::MAX,
        }
    }
}

impl SupervisorConfig {
    /// Map a [`pressure`] reading to the active degradation tier.
    pub fn tier_for(&self, pressure: u64) -> DegradeTier {
        if pressure >= self.shed_pressure {
            DegradeTier::Shed
        } else if pressure >= self.serve_stale_pressure {
            DegradeTier::ServeStale
        } else if pressure >= self.defer_cold_pressure {
            DegradeTier::DeferCold
        } else {
            DegradeTier::Nominal
        }
    }
}

/// Fleet pressure signal: ready-queue depth scaled by the resident
/// footprint in MiB (+1 so depth alone still registers). Monotone in
/// both inputs; unitless.
pub fn pressure(ready_depth: usize, resident_bytes: usize) -> u64 {
    (ready_depth as u64).saturating_mul(1 + (resident_bytes >> 20) as u64)
}

// ---------------------------------------------------------------------------
// Checkpoint codec
// ---------------------------------------------------------------------------

/// Checkpoint magic bytes.
pub const CHECKPOINT_MAGIC: [u8; 8] = *b"TSISCCKP";
/// Checkpoint format version. Bump on any layout change; the decoder
/// refuses unknown versions with a typed error instead of misparsing.
pub const CHECKPOINT_VERSION: u16 = 1;

/// One band's serialized state. Stamps are `(plane, x, y, t_write)` in
/// band-local coordinates — exactly what
/// `BandWriter::export_state` / `BandScorer::export_state` walk and what
/// their `restore_state` replays through the position-stable mismatch
/// assignment.
#[derive(Clone, Debug, PartialEq)]
pub enum BandCheckpoint {
    /// A write band: event count + array stamps.
    Writer {
        /// Band index.
        band: u16,
        /// Events processed (accounting restored verbatim).
        processed: u64,
        /// Nonzero `t_write` stamps.
        stamps: Vec<(u8, u16, u16, u64)>,
    },
    /// A scorer band: denoise tally + backend stamps (band + halo).
    Scorer {
        /// Band index.
        band: u16,
        /// Keep/drop accounting restored verbatim.
        tally: ShardTally,
        /// Nonzero backend stamps.
        stamps: Vec<(u8, u16, u16, u64)>,
    },
}

impl BandCheckpoint {
    /// Band index this checkpoint belongs to.
    pub fn band(&self) -> u16 {
        match self {
            BandCheckpoint::Writer { band, .. } | BandCheckpoint::Scorer { band, .. } => *band,
        }
    }
}

/// A whole session's serialized state: a config fingerprint guard, the
/// session window clock and counter block, and every band.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionCheckpoint {
    /// [`config_fingerprint`] of the session the checkpoint came from.
    /// Restore refuses a mismatch — replaying stamps into a differently
    /// shaped pipeline would silently produce wrong frames.
    pub fingerprint: u64,
    /// Session window clock (`next_frame`).
    pub next_frame: u64,
    /// Opaque session counter block (order owned by `serve::session`).
    pub counters: Vec<u64>,
    /// Per-band states.
    pub bands: Vec<BandCheckpoint>,
}

/// Typed checkpoint decode/verify failures. Every way a blob can be
/// wrong is a variant — corruption is *detected*, never applied.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CheckpointError {
    /// Blob shorter than the fixed header + trailer.
    TooShort,
    /// Magic bytes are not `TSISCCKP`.
    BadMagic,
    /// Unknown format version.
    BadVersion(u16),
    /// Trailing CRC-32 does not match the body.
    CrcMismatch,
    /// Body ended mid-field.
    Truncated,
    /// Unknown band-kind tag.
    BadBandKind(u8),
    /// Fingerprint does not match the restoring session's config.
    ConfigMismatch {
        /// Fingerprint the restoring session computed.
        expected: u64,
        /// Fingerprint stored in the checkpoint.
        found: u64,
    },
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::TooShort => write!(f, "checkpoint too short for header + CRC"),
            CheckpointError::BadMagic => write!(f, "checkpoint magic mismatch"),
            CheckpointError::BadVersion(v) => write!(f, "unknown checkpoint version {v}"),
            CheckpointError::CrcMismatch => write!(f, "checkpoint CRC mismatch (corrupt blob)"),
            CheckpointError::Truncated => write!(f, "checkpoint body truncated mid-field"),
            CheckpointError::BadBandKind(k) => write!(f, "unknown band checkpoint kind {k}"),
            CheckpointError::ConfigMismatch { expected, found } => write!(
                f,
                "checkpoint config fingerprint {found:#018x} does not match session {expected:#018x}"
            ),
        }
    }
}

impl std::error::Error for CheckpointError {}

/// FNV-1a fingerprint of the session shape (pipeline config + geometry
/// + end time). Two sessions share a fingerprint iff their checkpoints
/// are interchangeable.
pub fn config_fingerprint(cfg: &PipelineConfig, res: Resolution, t_end_us: u64) -> u64 {
    let canon = format!("{cfg:?}|{res:?}|{t_end_us}");
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in canon.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn push_stamps(out: &mut Vec<u8>, stamps: &[(u8, u16, u16, u64)]) {
    out.extend_from_slice(&(stamps.len() as u32).to_le_bytes());
    for &(plane, x, y, t) in stamps {
        out.push(plane);
        out.extend_from_slice(&x.to_le_bytes());
        out.extend_from_slice(&y.to_le_bytes());
        out.extend_from_slice(&t.to_le_bytes());
    }
}

/// Serialize a [`SessionCheckpoint`]: magic, version, fingerprint,
/// clock, counters, bands, then a trailing CRC-32 over everything
/// before it (same polynomial as the wire frames —
/// [`crate::serve::net::frame::crc32`]).
pub fn encode_checkpoint(ck: &SessionCheckpoint) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&CHECKPOINT_MAGIC);
    out.extend_from_slice(&CHECKPOINT_VERSION.to_le_bytes());
    out.extend_from_slice(&ck.fingerprint.to_le_bytes());
    out.extend_from_slice(&ck.next_frame.to_le_bytes());
    out.extend_from_slice(&(ck.counters.len() as u16).to_le_bytes());
    for c in &ck.counters {
        out.extend_from_slice(&c.to_le_bytes());
    }
    out.extend_from_slice(&(ck.bands.len() as u16).to_le_bytes());
    for b in &ck.bands {
        match b {
            BandCheckpoint::Writer { band, processed, stamps } => {
                out.push(0);
                out.extend_from_slice(&band.to_le_bytes());
                out.extend_from_slice(&processed.to_le_bytes());
                push_stamps(&mut out, stamps);
            }
            BandCheckpoint::Scorer { band, tally, stamps } => {
                out.push(1);
                out.extend_from_slice(&band.to_le_bytes());
                for v in [tally.scored, tally.kept, tally.dropped, tally.halo_ingests] {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                push_stamps(&mut out, stamps);
            }
        }
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Panic-free little-endian cursor over a checkpoint body.
struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CheckpointError> {
        if self.pos + n > self.b.len() {
            return Err(CheckpointError::Truncated);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CheckpointError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, CheckpointError> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32, CheckpointError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, CheckpointError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn stamps(&mut self) -> Result<Vec<(u8, u16, u16, u64)>, CheckpointError> {
        let n = self.u32()? as usize;
        // Each stamp is 13 encoded bytes; bound before allocating so a
        // corrupt length can't balloon memory (the CRC already passed,
        // but defense in depth is free here).
        if n * 13 > self.b.len() - self.pos {
            return Err(CheckpointError::Truncated);
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let plane = self.u8()?;
            let x = self.u16()?;
            let y = self.u16()?;
            let t = self.u64()?;
            v.push((plane, x, y, t));
        }
        Ok(v)
    }
}

/// Parse and CRC-verify a checkpoint blob. Succeeds only on a blob
/// [`encode_checkpoint`] produced, bit-for-bit; any corruption lands in
/// a typed [`CheckpointError`].
pub fn decode_checkpoint(bytes: &[u8]) -> Result<SessionCheckpoint, CheckpointError> {
    // magic(8) + version(2) + fingerprint(8) + clock(8) + counter
    // count(2) + band count(2) + crc(4)
    if bytes.len() < 34 {
        return Err(CheckpointError::TooShort);
    }
    let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
    if crc32(body) != stored {
        return Err(CheckpointError::CrcMismatch);
    }
    let mut r = Rd { b: body, pos: 0 };
    if r.take(8)? != CHECKPOINT_MAGIC {
        return Err(CheckpointError::BadMagic);
    }
    let version = r.u16()?;
    if version != CHECKPOINT_VERSION {
        return Err(CheckpointError::BadVersion(version));
    }
    let fingerprint = r.u64()?;
    let next_frame = r.u64()?;
    let n_counters = r.u16()? as usize;
    let mut counters = Vec::with_capacity(n_counters);
    for _ in 0..n_counters {
        counters.push(r.u64()?);
    }
    let n_bands = r.u16()? as usize;
    let mut bands = Vec::with_capacity(n_bands);
    for _ in 0..n_bands {
        let kind = r.u8()?;
        let band = r.u16()?;
        match kind {
            0 => {
                let processed = r.u64()?;
                let stamps = r.stamps()?;
                bands.push(BandCheckpoint::Writer { band, processed, stamps });
            }
            1 => {
                let tally = ShardTally {
                    scored: r.u64()?,
                    kept: r.u64()?,
                    dropped: r.u64()?,
                    halo_ingests: r.u64()?,
                };
                let stamps = r.stamps()?;
                bands.push(BandCheckpoint::Scorer { band, tally, stamps });
            }
            k => return Err(CheckpointError::BadBandKind(k)),
        }
    }
    if r.pos != body.len() {
        // Trailing garbage would have broken the CRC, but a hand-built
        // blob could pad consistently; refuse it anyway.
        return Err(CheckpointError::Truncated);
    }
    Ok(SessionCheckpoint { fingerprint, next_frame, counters, bands })
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    fn sample_checkpoint() -> SessionCheckpoint {
        SessionCheckpoint {
            fingerprint: 0xdead_beef_cafe_f00d,
            next_frame: 150_000,
            counters: vec![7, 0, 42, u64::MAX, 3],
            bands: vec![
                BandCheckpoint::Writer {
                    band: 0,
                    processed: 11,
                    stamps: vec![(0, 3, 1, 100), (1, 5, 2, 250)],
                },
                BandCheckpoint::Writer { band: 1, processed: 0, stamps: vec![] },
                BandCheckpoint::Scorer {
                    band: 2,
                    tally: ShardTally { scored: 9, kept: 6, dropped: 3, halo_ingests: 2 },
                    stamps: vec![(0, 0, 0, 1), (1, 319, 239, 999_999)],
                },
            ],
        }
    }

    #[test]
    fn checkpoint_roundtrip_is_exact() {
        let ck = sample_checkpoint();
        let bytes = encode_checkpoint(&ck);
        assert_eq!(decode_checkpoint(&bytes).expect("roundtrip"), ck);
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        // The round-trip law's dual: no single-bit corruption anywhere
        // in the blob decodes successfully (CRC catches body flips, and
        // CRC-field flips mismatch the body).
        let bytes = encode_checkpoint(&sample_checkpoint());
        for i in 0..bytes.len() {
            for bit in 0..8 {
                let mut bad = bytes.clone();
                bad[i] ^= 1 << bit;
                assert!(
                    decode_checkpoint(&bad).is_err(),
                    "flip of byte {i} bit {bit} went undetected"
                );
            }
        }
    }

    #[test]
    fn typed_errors_for_magic_version_and_truncation() {
        let ck = sample_checkpoint();
        let good = encode_checkpoint(&ck);

        assert_eq!(decode_checkpoint(&[1, 2, 3]), Err(CheckpointError::TooShort));

        // Re-CRC after tampering so the specific typed error (not
        // CrcMismatch) is reachable.
        let recrc = |mut body: Vec<u8>| {
            body.truncate(body.len() - 4);
            let crc = crc32(&body);
            body.extend_from_slice(&crc.to_le_bytes());
            body
        };

        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert_eq!(decode_checkpoint(&recrc(bad_magic)), Err(CheckpointError::BadMagic));

        let mut bad_version = good.clone();
        bad_version[8] = 99;
        assert_eq!(decode_checkpoint(&recrc(bad_version)), Err(CheckpointError::BadVersion(99)));

        // Truncate mid-body and re-CRC: Truncated, not CrcMismatch.
        let mut cut = good.clone();
        cut.truncate(good.len() - 20);
        let cut = recrc(cut);
        assert_eq!(decode_checkpoint(&cut), Err(CheckpointError::Truncated));
    }

    #[test]
    fn fingerprint_separates_configs_and_is_stable() {
        let res = Resolution::new(64, 48);
        let a = PipelineConfig::default();
        let mut b = PipelineConfig::default();
        b.window_us += 1;
        assert_eq!(config_fingerprint(&a, res, 1000), config_fingerprint(&a, res, 1000));
        assert_ne!(config_fingerprint(&a, res, 1000), config_fingerprint(&b, res, 1000));
        assert_ne!(config_fingerprint(&a, res, 1000), config_fingerprint(&a, res, 2000));
        assert_ne!(
            config_fingerprint(&a, res, 1000),
            config_fingerprint(&a, Resolution::new(48, 64), 1000)
        );
    }

    #[test]
    fn fault_plans_are_deterministic_per_seed_and_kind() {
        // Mirrors `net::faults::injector_is_deterministic_per_seed_and_kind`.
        for kind in SchedFaultKind::ALL {
            let a = SchedFaultPlan::from_seed(kind, 0xC4A0_5EED);
            let b = SchedFaultPlan::from_seed(kind, 0xC4A0_5EED);
            assert_eq!(a, b, "same (seed, kind) must replay the same plan");
            let c = SchedFaultPlan::from_seed(kind, 0xC4A0_5EEE);
            assert!(a.fire_on_job >= 1, "ordinals are 1-based");
            // Different seeds may rarely collide on one field, but the
            // whole plan (incl. 64-bit salt) must differ.
            assert_ne!(a, c, "different seeds must differ");
        }
        // Distinct kinds draw from distinct streams.
        let p = SchedFaultPlan::from_seed(SchedFaultKind::JobPanic, 7);
        let s = SchedFaultPlan::from_seed(SchedFaultKind::JobStall, 7);
        assert_ne!(p.corrupt_salt, s.corrupt_salt);
    }

    #[test]
    fn armed_panic_fires_exactly_once_on_its_ordinal() {
        let plan = SchedFaultPlan {
            kind: SchedFaultKind::JobPanic,
            fire_on_job: 3,
            stall_ms: 0,
            corrupt_salt: 0,
        };
        let armed = ArmedFault::new(plan);
        let counters = SupervisorCounters::new();
        for n in 1..=5u64 {
            let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                armed.before_job(&counters)
            }))
            .is_err();
            assert_eq!(hit, n == 3, "job #{n}");
        }
        assert!(armed.has_fired());
        assert_eq!(counters.snapshot(0, 0, false).injected_panics, 1);
    }

    #[test]
    fn armed_stall_counts_once_and_never_panics() {
        let plan = SchedFaultPlan {
            kind: SchedFaultKind::JobStall,
            fire_on_job: 1,
            stall_ms: 1,
            corrupt_salt: 0,
        };
        let armed = ArmedFault::new(plan);
        let counters = SupervisorCounters::new();
        for _ in 0..4 {
            armed.before_job(&counters);
        }
        assert_eq!(counters.snapshot(0, 0, false).injected_stalls, 1);
    }

    #[test]
    fn corruption_flips_one_bit_and_decode_detects_it() {
        let plan = SchedFaultPlan::from_seed(SchedFaultKind::CheckpointCorrupt, 42);
        let armed = ArmedFault::new(plan);
        let counters = SupervisorCounters::new();
        // before_job is inert for corruption plans.
        armed.before_job(&counters);
        assert!(!armed.has_fired());

        let good = encode_checkpoint(&sample_checkpoint());
        let mut bad = good.clone();
        assert!(armed.corrupt_checkpoint(&mut bad, &counters));
        let diff: u32 =
            good.iter().zip(&bad).map(|(a, b)| (a ^ b).count_ones()).sum();
        assert_eq!(diff, 1, "exactly one bit flipped");
        assert_eq!(decode_checkpoint(&bad), Err(CheckpointError::CrcMismatch));
        // At most once.
        let mut again = good.clone();
        assert!(!armed.corrupt_checkpoint(&mut again, &counters));
        assert_eq!(again, good);
        assert_eq!(counters.snapshot(0, 0, false).injected_checkpoint_corruptions, 1);
    }

    #[test]
    fn fault_board_files_counts_and_clears() {
        let board = FaultBoard::new();
        assert!(!board.is_quarantined());
        board.file(SessionFault {
            band: 2,
            job: FaultJobKind::Write,
            detail: "injected".into(),
            recent: Vec::new(),
        });
        board.file(SessionFault {
            band: 3,
            job: FaultJobKind::Snapshot,
            detail: "boom".into(),
            recent: vec![FlightSample {
                seq: 1,
                band: 3,
                job: FaultJobKind::Write,
                queue_wait_us: 5,
                service_us: 9,
            }],
        });
        assert!(board.is_quarantined());
        assert_eq!(board.count(), 2);
        let faults = board.faults();
        assert_eq!(faults.len(), 2);
        assert_eq!(faults[0].job, FaultJobKind::Write);
        assert!(faults[1].to_string().contains("snapshot job panicked on band 3"));
        board.clear();
        assert!(!board.is_quarantined());
        assert!(board.faults().is_empty());
    }

    #[test]
    fn degrade_tiers_escalate_with_pressure() {
        let cfg = SupervisorConfig {
            defer_cold_pressure: 10,
            serve_stale_pressure: 100,
            shed_pressure: 1000,
            ..SupervisorConfig::default()
        };
        assert_eq!(cfg.tier_for(0), DegradeTier::Nominal);
        assert_eq!(cfg.tier_for(9), DegradeTier::Nominal);
        assert_eq!(cfg.tier_for(10), DegradeTier::DeferCold);
        assert_eq!(cfg.tier_for(100), DegradeTier::ServeStale);
        assert_eq!(cfg.tier_for(5000), DegradeTier::Shed);
        assert!(DegradeTier::Shed > DegradeTier::ServeStale);
        assert!(DegradeTier::ServeStale > DegradeTier::DeferCold);
        assert!(DegradeTier::DeferCold > DegradeTier::Nominal);
        // Defaults never degrade.
        let dflt = SupervisorConfig::default();
        assert_eq!(dflt.tier_for(u64::MAX - 1), DegradeTier::Nominal);
    }

    #[test]
    fn pressure_is_monotone_and_overflow_safe() {
        assert_eq!(pressure(0, 0), 0);
        assert_eq!(pressure(4, 0), 4);
        assert_eq!(pressure(4, 3 << 20), 16);
        assert!(pressure(7, 1 << 30) > pressure(7, 1 << 20));
        let _ = pressure(usize::MAX, usize::MAX); // saturates, no panic
    }

    #[test]
    fn counters_snapshot_maps_every_field() {
        let c = SupervisorCounters::new();
        c.quarantines.add(1);
        c.deadline_misses.add(2);
        c.deferred_cold_snapshots.add(3);
        c.stale_frames_served.add(4);
        c.sessions_shed_overloaded.add(5);
        c.checkpoints_taken.add(6);
        c.checkpoint_corruptions_detected.add(7);
        c.restores_completed.add(8);
        c.injected_panics.add(9);
        c.injected_stalls.add(10);
        c.injected_checkpoint_corruptions.add(11);
        let s = c.snapshot(20, 21, true);
        assert_eq!(
            s,
            SupervisorStats {
                quarantines: 1,
                worker_panics: 20,
                worker_respawns: 21,
                fleet_degraded: true,
                deadline_misses: 2,
                deferred_cold_snapshots: 3,
                stale_frames_served: 4,
                sessions_shed_overloaded: 5,
                checkpoints_taken: 6,
                checkpoint_corruptions_detected: 7,
                restores_completed: 8,
                injected_panics: 9,
                injected_stalls: 10,
                injected_checkpoint_corruptions: 11,
            }
        );
    }

    #[test]
    fn registered_counters_render_through_the_registry() {
        let reg = Registry::new();
        let c = SupervisorCounters::registered(&reg);
        c.quarantines.inc();
        c.checkpoints_taken.add(3);
        let text = reg.render();
        assert!(text.contains("quarantines_total 1"));
        assert!(text.contains("checkpoints_taken_total 3"));
        assert!(text.contains("injected_stalls_total 0"), "zero counters still render");
    }
}
