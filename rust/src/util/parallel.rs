//! Scoped-thread row parallelism for frame readout.
//!
//! The paper's tiled analog readout is embarrassingly parallel by
//! construction: every pixel is sampled concurrently, and the digital
//! twin inherits that shape — a frame is a set of independent per-row
//! evaluations into disjoint output rows. This module provides the three
//! pieces every parallel readout path shares:
//!
//! * [`auto_chunks`] — how many row chunks to render concurrently
//!   (`std::thread::available_parallelism`, gated by a minimum amount of
//!   work so small frames never pay a thread-spawn);
//! * [`balanced_row_ranges`] — a contiguous partition of the rows into
//!   chunks of roughly equal *weight* (per-row active-pixel counts), so
//!   threads stay balanced when activity clusters in a few bands;
//! * [`for_each_row_chunk`] — run a renderer over each chunk's disjoint
//!   mutable row slab, on scoped `std` threads (no external deps; one
//!   chunk degenerates to an inline call with no spawn).
//!
//! Because each chunk owns a disjoint slab of output rows and every
//! pixel's value is a pure function of immutable shared state, a chunked
//! render is **bit-for-bit identical** to the single-threaded render for
//! every chunk count (asserted in `tests/readout_equiv.rs`).

use crate::util::grid::Grid;
use std::ops::Range;

/// Below this many output pixels a frame render stays single-threaded:
/// thread spawn/join costs on the order of the whole render.
pub const MIN_PAR_PIXELS: usize = 1 << 15;

/// Worker threads the host offers (≥ 1; 1 when the query fails).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Chunk count for a render covering `work_pixels` output pixels: all
/// available cores, or 1 below the [`MIN_PAR_PIXELS`] work gate.
pub fn auto_chunks(work_pixels: usize) -> usize {
    if work_pixels < MIN_PAR_PIXELS {
        1
    } else {
        available_threads()
    }
}

/// Horizontal band partition shared by every band-sharded stage (the
/// write router's shard bands and the STCF denoise shards): `requested`
/// bands over `height` rows. Returns `(band_h, n_bands)` with the band
/// height rounded up and the effective band count recomputed so no band
/// owns zero rows (e.g. 8 rows over 6 requested bands → bands of 2 →
/// 4 bands). Band `s` owns rows `s·band_h .. min((s+1)·band_h, height)`.
pub fn band_layout(height: usize, requested: usize) -> (usize, usize) {
    assert!(height > 0, "empty band layout");
    let requested = requested.max(1).min(height);
    let band_h = height.div_ceil(requested);
    (band_h, height.div_ceil(band_h))
}

// (The per-shard RNG seed derivation that used to live here is gone:
// band-sharded stages now anchor their arrays with
// `IscConfig::origin_y` and the position-stable mismatch hash
// `crate::isc::param_index_at`, so every shard shares the full-sensor
// seed and samples the exact window of its parameter map.)

/// Partition rows `0..weights.len()` into at most `chunks` contiguous,
/// non-empty ranges of roughly equal total weight (greedy prefix cut at
/// the ideal cumulative targets). `weights[y]` is the per-row work
/// estimate — active-pixel count for list readout, the row width for a
/// dense scan. Always covers every row; returns fewer ranges than
/// requested when there are fewer rows than chunks.
pub fn balanced_row_ranges(weights: &[usize], chunks: usize) -> Vec<Range<usize>> {
    let rows = weights.len();
    if rows == 0 {
        return Vec::new();
    }
    let chunks = chunks.clamp(1, rows);
    let total: usize = weights.iter().sum();
    let mut ranges = Vec::with_capacity(chunks);
    let mut start = 0usize;
    let mut prefix = 0usize;
    for k in 0..chunks {
        if start >= rows {
            break;
        }
        if k == chunks - 1 {
            ranges.push(start..rows);
            break;
        }
        // Leave at least one row for each later chunk.
        let max_end = rows - (chunks - k - 1);
        let target = total * (k + 1) / chunks;
        let mut end = start + 1;
        prefix += weights[start];
        while end < max_end && prefix < target {
            prefix += weights[end];
            end += 1;
        }
        ranges.push(start..end);
        start = end;
    }
    ranges
}

/// Render each range's rows on its own scoped thread: `f(range, slab)`
/// receives the row range and the matching disjoint mutable slab of
/// `out` (rows `range.start..range.end`, row-major). Ranges must be the
/// sorted, contiguous cover produced by [`balanced_row_ranges`]. A
/// single range runs inline with no thread spawn.
pub fn for_each_row_chunk<T, F>(out: &mut Grid<T>, ranges: &[Range<usize>], f: F)
where
    T: Clone + Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    let mut slabs = out.row_slabs_mut(ranges);
    if slabs.len() <= 1 {
        if let (Some(slab), Some(range)) = (slabs.pop(), ranges.first()) {
            f(range.clone(), slab);
        }
        return;
    }
    std::thread::scope(|scope| {
        for (range, slab) in ranges.iter().zip(slabs) {
            let f = &f;
            scope.spawn(move || f(range.clone(), slab));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cover_ok(ranges: &[Range<usize>], rows: usize) {
        assert!(!ranges.is_empty());
        assert_eq!(ranges[0].start, 0);
        assert_eq!(ranges.last().unwrap().end, rows);
        for w in ranges.windows(2) {
            assert_eq!(w[0].end, w[1].start, "ranges must be contiguous");
        }
        assert!(ranges.iter().all(|r| r.start < r.end), "no empty ranges");
    }

    #[test]
    fn uniform_weights_split_evenly() {
        let ranges = balanced_row_ranges(&[1; 12], 4);
        cover_ok(&ranges, 12);
        assert_eq!(ranges.len(), 4);
        assert!(ranges.iter().all(|r| r.end - r.start == 3), "{ranges:?}");
    }

    #[test]
    fn fewer_rows_than_chunks_yields_one_row_each() {
        let ranges = balanced_row_ranges(&[5, 5, 5], 8);
        cover_ok(&ranges, 3);
        assert_eq!(ranges.len(), 3);
    }

    #[test]
    fn clustered_weight_isolates_the_hot_rows() {
        // All the activity in rows 0..2: the first chunk must not also
        // swallow the whole cold tail.
        let mut w = vec![0usize; 16];
        w[0] = 1_000;
        w[1] = 1_000;
        let ranges = balanced_row_ranges(&w, 4);
        cover_ok(&ranges, 16);
        assert_eq!(ranges.len(), 4);
        assert!(ranges[0].end <= 2, "hot rows confined to the first chunk: {ranges:?}");
    }

    #[test]
    fn zero_total_weight_still_covers() {
        let ranges = balanced_row_ranges(&[0; 7], 3);
        cover_ok(&ranges, 7);
        assert_eq!(ranges.len(), 3);
    }

    #[test]
    fn single_chunk_is_everything() {
        let ranges = balanced_row_ranges(&[3, 1, 4], 1);
        assert_eq!(ranges, vec![0..3]);
    }

    #[test]
    fn zero_rows_yields_no_ranges() {
        // A fully inactive frame (no weights at all) partitions to
        // nothing — callers render no chunks rather than spawning
        // threads over an empty cover.
        assert!(balanced_row_ranges(&[], 4).is_empty());
        assert!(balanced_row_ranges(&[], 1).is_empty());
    }

    #[test]
    fn zero_chunks_clamps_to_one() {
        let ranges = balanced_row_ranges(&[2, 2, 2], 0);
        assert_eq!(ranges, vec![0..3]);
    }

    #[test]
    fn one_row_many_chunks_degenerates_to_one_range() {
        let ranges = balanced_row_ranges(&[42], 16);
        assert_eq!(ranges, vec![0..1]);
    }

    #[test]
    fn extreme_skew_never_produces_empty_ranges() {
        // One enormous row at each end, nothing between: the prefix-cut
        // targets all collapse onto the ends, which must not starve the
        // middle chunks of their guaranteed row.
        let mut w = vec![0usize; 10];
        w[0] = 1_000_000;
        w[9] = 1_000_000;
        let ranges = balanced_row_ranges(&w, 5);
        cover_ok(&ranges, 10);
    }

    #[test]
    fn empty_range_list_renders_nothing() {
        // The `balanced_row_ranges(&[], _)` cover: no chunks, renderer
        // never runs, grid untouched.
        let mut g = Grid::new(4, 3, 7i64);
        for_each_row_chunk(&mut g, &[], |_range, _slab| {
            panic!("no ranges — renderer must never run");
        });
        assert!(g.as_slice().iter().all(|&v| v == 7));
    }

    #[test]
    fn row_chunks_write_disjoint_slabs() {
        let mut g = Grid::new(4, 9, 0i64);
        let ranges = balanced_row_ranges(&[1; 9], 3);
        for_each_row_chunk(&mut g, &ranges, |range, slab| {
            assert_eq!(slab.len(), (range.end - range.start) * 4);
            for (i, v) in slab.iter_mut().enumerate() {
                *v = (range.start * 4 + i) as i64;
            }
        });
        // Every cell holds its own row-major index: full disjoint cover.
        for (i, &v) in g.as_slice().iter().enumerate() {
            assert_eq!(v, i as i64);
        }
    }

    #[test]
    fn band_layout_covers_without_empty_bands() {
        assert_eq!(band_layout(16, 4), (4, 4));
        assert_eq!(band_layout(8, 6), (2, 4), "rounding must drop empty bands");
        assert_eq!(band_layout(10, 4), (3, 4)); // bands of 3,3,3,1
        assert_eq!(band_layout(5, 1), (5, 1));
        assert_eq!(band_layout(3, 100), (1, 3), "never more bands than rows");
        for h in 1..40usize {
            for req in 1..12usize {
                let (band_h, n) = band_layout(h, req);
                assert!(n >= 1 && n <= req.min(h).max(1));
                assert!((n - 1) * band_h < h && n * band_h >= h, "h={h} req={req}");
            }
        }
    }

    #[test]
    fn auto_chunks_gates_small_work() {
        assert_eq!(auto_chunks(0), 1);
        assert_eq!(auto_chunks(MIN_PAR_PIXELS - 1), 1);
        assert_eq!(auto_chunks(MIN_PAR_PIXELS), available_threads());
        assert!(available_threads() >= 1);
    }
}
