//! The generic (actor, job) worker pool behind the serve scheduler.
//!
//! A fixed-size thread pool executes jobs queued on per-actor FIFOs.
//! This module is deliberately free of any band/session semantics — it
//! is the pure scheduling core extracted from `serve::scheduler` so the
//! loom models in `tests/loom_sched.rs` can model-check the **actual**
//! production queue logic with trivial slots and jobs. The serve layer
//! instantiates it with `(BandSlot, Job)` and supplies the job runner.
//!
//! ## Invariants (model-checked under `--cfg loom`)
//!
//! * **At most once scheduled** — an actor sits in the global ready
//!   queue at most once (`scheduled` flag), and is processed by at most
//!   one worker at a time; jobs on one actor can never run concurrently
//!   or out of order.
//! * **Per-actor FIFO** — jobs execute strictly in enqueue order; a job
//!   queued before another on the same actor is observed by it.
//! * **One job per turn** — a worker runs one job, then re-queues the
//!   actor at the ready-queue tail if work remains: round-robin
//!   fairness across every actor with pending jobs.
//! * **No lost wakeups** — every enqueue that transitions an actor to
//!   scheduled signals the pool condvar; parked workers always observe
//!   shutdown and hold-release transitions.
//! * **Drain quiescence** — while a [`Hold`] is live, no *new* job
//!   starts (workers finish their current job, then idle); dropping the
//!   last hold resumes draining, and `shutdown` drains every queued job
//!   even while held.
//!
//! The runner executes with the actor's slot checked out of the actor
//! lock, so producers enqueue without ever blocking on job execution.
//!
//! ## Panic isolation and supervision
//!
//! Every runner invocation goes through
//! [`catch_boundary`](crate::util::sync::catch_boundary): a panicking
//! job is counted ([`ActorPool::jobs_panicked`]) and the worker puts
//! the slot back, clears or requeues the `scheduled` flag, and keeps
//! serving — a panic can never leak the slot or wedge the actor's
//! FIFO (the historical failure mode: a lost `scheduled` flag starved
//! that actor forever and `shutdown` then panicked on the dead
//! worker's join handle). Workers that die anyway (a panic outside
//! the boundary, e.g. pool-lock poisoning) file a report on the
//! [`DeathBoard`] via an armed drop guard; a pool built with
//! [`ActorPool::with_supervision`] runs a supervisor thread that
//! respawns dead workers within a [`RestartBudget`] and raises the
//! fleet-level [`ActorPool::degraded`] flag once the budget is spent.
//! The board also accepts external reports, which is how the respawn
//! path stays testable in a world where the boundary makes organic
//! worker death nearly impossible.

use crate::util::sync::{catch_boundary, thread, Arc, AtomicU64, Condvar, Mutex, Ordering};
use std::collections::VecDeque;

/// One actor: a FIFO of jobs plus a slot of actor-local state handed to
/// the runner with every job.
pub struct Actor<S, J> {
    inner: Mutex<ActorInner<S, J>>,
}

struct ActorInner<S, J> {
    jobs: VecDeque<J>,
    /// True while the actor sits in the ready queue or on a worker.
    scheduled: bool,
    /// None only while a worker has the slot checked out.
    slot: Option<S>,
}

struct ReadyQueue<S, J> {
    ready: VecDeque<Arc<Actor<S, J>>>,
    /// Outstanding [`Hold`]s: workers idle while > 0 (drain gate).
    holds: usize,
    shutdown: bool,
}

type Runner<S, J> = dyn Fn(J, &mut S) + Send + Sync;

struct PoolShared<S, J> {
    queue: Mutex<ReadyQueue<S, J>>,
    cv: Condvar,
    jobs_executed: AtomicU64,
    jobs_panicked: AtomicU64,
    runner: Box<Runner<S, J>>,
}

/// Where dying workers (and external observers) report worker deaths,
/// and where the supervisor thread waits for them.
///
/// A tiny MPSC hand-off on the loom-switchable facade: `report` never
/// blocks, `wait_next` parks until a death or `close`. Each reported
/// death is consumed by exactly one `wait_next` (at-most-once respawn
/// per death), and `close` wakes every parked waiter — both properties
/// are model-checked in `tests/loom_sched.rs`.
pub struct DeathBoard {
    inner: Mutex<DeathBoardInner>,
    cv: Condvar,
}

struct DeathBoardInner {
    deaths: VecDeque<usize>,
    closed: bool,
}

impl DeathBoard {
    /// An empty, open board.
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(DeathBoardInner { deaths: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// File worker `id`'s death. Never blocks; wakes one waiter.
    pub fn report(&self, id: usize) {
        let mut inner = self.inner.lock().expect("death board lock");
        inner.deaths.push_back(id);
        drop(inner);
        self.cv.notify_one();
    }

    /// Block until a death is available (consuming it) or the board is
    /// closed (`None`). Each death is handed to exactly one caller.
    pub fn wait_next(&self) -> Option<usize> {
        let mut inner = self.inner.lock().expect("death board lock");
        loop {
            if let Some(id) = inner.deaths.pop_front() {
                return Some(id);
            }
            if inner.closed {
                return None;
            }
            inner = self.cv.wait(inner).expect("death board lock");
        }
    }

    /// Close the board: pending deaths remain consumable, new waiters
    /// return `None` once drained. Wakes every parked waiter.
    pub fn close(&self) {
        let mut inner = self.inner.lock().expect("death board lock");
        inner.closed = true;
        drop(inner);
        self.cv.notify_all();
    }
}

impl Default for DeathBoard {
    fn default() -> Self {
        Self::new()
    }
}

/// Pure sliding-window restart budget: at most `max_respawns` allowed
/// per `window_us` of caller-supplied time. Taking "now" as a parameter
/// keeps it unit-testable and free of clocks.
pub struct RestartBudget {
    max_respawns: u32,
    window_us: u64,
    grants: VecDeque<u64>,
}

impl RestartBudget {
    /// Budget of `max_respawns` grants per sliding `window_us`.
    pub fn new(max_respawns: u32, window_us: u64) -> Self {
        Self { max_respawns, window_us, grants: VecDeque::new() }
    }

    /// Whether a respawn at `now_us` fits the budget; a `true` return
    /// consumes one grant.
    pub fn allow(&mut self, now_us: u64) -> bool {
        while let Some(&front) = self.grants.front() {
            if now_us.saturating_sub(front) >= self.window_us {
                self.grants.pop_front();
            } else {
                break;
            }
        }
        if (self.grants.len() as u32) < self.max_respawns {
            self.grants.push_back(now_us);
            true
        } else {
            false
        }
    }
}

/// Supervisor policy for [`ActorPool::with_supervision`].
#[derive(Clone, Copy, Debug)]
pub struct SupervisionConfig {
    /// Worker respawns allowed per sliding window before the pool
    /// degrades.
    pub max_respawns: u32,
    /// The sliding budget window, microseconds of wall time.
    pub window_us: u64,
}

impl Default for SupervisionConfig {
    fn default() -> Self {
        Self { max_respawns: 4, window_us: 60_000_000 }
    }
}

struct Supervision {
    board: Arc<DeathBoard>,
    degraded: Arc<AtomicU64>,
    respawns: Arc<AtomicU64>,
    handle: Option<thread::JoinHandle<()>>,
}

/// The fixed worker fleet. See the module docs for the invariants.
pub struct ActorPool<S, J> {
    shared: Arc<PoolShared<S, J>>,
    handles: Vec<thread::JoinHandle<()>>,
    supervision: Option<Supervision>,
}

/// Pauses the pool while alive: workers finish their current job, then
/// idle; dropping the last outstanding hold resumes draining.
pub struct Hold<S, J> {
    shared: Arc<PoolShared<S, J>>,
}

impl<S, J> Drop for Hold<S, J> {
    fn drop(&mut self) {
        let mut q = self.shared.queue.lock().expect("pool lock");
        q.holds -= 1;
        if q.holds == 0 {
            self.shared.cv.notify_all();
        }
    }
}

impl<S: Send + 'static, J: Send + 'static> ActorPool<S, J> {
    /// Spawn `workers.max(1)` worker threads executing jobs through
    /// `runner`. The runner receives each job together with the owning
    /// actor's slot; it runs outside every pool lock.
    pub fn new<F>(workers: usize, runner: F) -> Self
    where
        F: Fn(J, &mut S) + Send + Sync + 'static,
    {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(ReadyQueue { ready: VecDeque::new(), holds: 0, shutdown: false }),
            cv: Condvar::new(),
            jobs_executed: AtomicU64::new(0),
            jobs_panicked: AtomicU64::new(0),
            runner: Box::new(runner),
        });
        let handles = (0..workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                thread::spawn(move || worker_loop(&shared, None))
            })
            .collect();
        Self { shared, handles, supervision: None }
    }

    /// Like [`ActorPool::new`], plus a supervisor thread: workers carry
    /// an armed death guard that files on the pool's [`DeathBoard`] if
    /// they die outside the panic boundary; the supervisor consumes
    /// each report, respawns a replacement within `cfg`'s restart
    /// budget, and sets the [`ActorPool::degraded`] flag once the
    /// budget is exhausted. Supervision is opt-in so loom models of the
    /// bare pool keep their small state space.
    pub fn with_supervision<F>(workers: usize, cfg: SupervisionConfig, runner: F) -> Self
    where
        F: Fn(J, &mut S) + Send + Sync + 'static,
    {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(ReadyQueue { ready: VecDeque::new(), holds: 0, shutdown: false }),
            cv: Condvar::new(),
            jobs_executed: AtomicU64::new(0),
            jobs_panicked: AtomicU64::new(0),
            runner: Box::new(runner),
        });
        let board = Arc::new(DeathBoard::new());
        let handles: Vec<_> = (0..workers.max(1))
            .map(|id| {
                let shared = shared.clone();
                let board = board.clone();
                thread::spawn(move || worker_loop(&shared, Some((board, id))))
            })
            .collect();
        let degraded = Arc::new(AtomicU64::new(0));
        let respawns = Arc::new(AtomicU64::new(0));
        let handle = {
            let shared = shared.clone();
            let board = board.clone();
            let degraded = degraded.clone();
            let respawns = respawns.clone();
            let next_id = handles.len();
            thread::spawn(move || {
                supervisor_loop(&shared, &board, cfg, &degraded, &respawns, next_id)
            })
        };
        Self {
            shared,
            handles,
            supervision: Some(Supervision { board, degraded, respawns, handle: Some(handle) }),
        }
    }

    /// Worker-thread count (fixed at construction).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Register a new actor owning `slot`.
    pub fn spawn_actor(&self, slot: S) -> Arc<Actor<S, J>> {
        Arc::new(Actor {
            inner: Mutex::new(ActorInner {
                jobs: VecDeque::new(),
                scheduled: false,
                slot: Some(slot),
            }),
        })
    }

    /// Enqueue `job` on `actor`'s FIFO; schedules the actor if idle.
    /// Never blocks on job execution — bound the *number* of queued
    /// jobs at the producer (admission control), not here.
    pub fn enqueue(&self, actor: &Arc<Actor<S, J>>, job: J) {
        let newly_scheduled = {
            let mut inner = actor.inner.lock().expect("actor lock");
            inner.jobs.push_back(job);
            if inner.scheduled {
                false
            } else {
                inner.scheduled = true;
                true
            }
        };
        if newly_scheduled {
            let mut q = self.shared.queue.lock().expect("pool lock");
            q.ready.push_back(actor.clone());
            drop(q);
            self.shared.cv.notify_one();
        }
    }

    /// Jobs executed pool-wide since construction.
    pub fn jobs_executed(&self) -> u64 {
        self.shared.jobs_executed.load(Ordering::Relaxed)
    }

    /// Jobs whose runner panicked (caught at the supervision boundary;
    /// the worker survived and the actor stayed schedulable).
    pub fn jobs_panicked(&self) -> u64 {
        self.shared.jobs_panicked.load(Ordering::Relaxed)
    }

    /// Workers respawned by the supervisor (0 without supervision).
    pub fn worker_respawns(&self) -> u64 {
        self.supervision.as_ref().map_or(0, |s| s.respawns.load(Ordering::Relaxed))
    }

    /// True once the supervisor exhausted its restart budget — the
    /// fleet is running with fewer workers than configured.
    pub fn degraded(&self) -> bool {
        self.supervision.as_ref().is_some_and(|s| s.degraded.load(Ordering::Relaxed) != 0)
    }

    /// The supervised pool's death board (None without supervision).
    /// External observers (tests, a higher layer that watched a worker
    /// wedge) may file reports here; each report triggers at most one
    /// respawn.
    pub fn death_board(&self) -> Option<Arc<DeathBoard>> {
        self.supervision.as_ref().map(|s| s.board.clone())
    }

    /// Actors currently waiting in the global ready queue.
    pub fn ready_depth(&self) -> usize {
        self.shared.queue.lock().expect("pool lock").ready.len()
    }

    /// Pause draining until the guard drops (see [`Hold`]).
    pub fn hold(&self) -> Hold<S, J> {
        self.shared.queue.lock().expect("pool lock").holds += 1;
        Hold { shared: self.shared.clone() }
    }

    /// Stop the pool: workers drain every queued job (holds included),
    /// then exit. Tolerates dead workers — a worker that died mid-life
    /// was already reported and (under supervision) replaced; its join
    /// error must not poison the teardown of the survivors.
    pub fn shutdown(mut self) {
        {
            let mut q = self.shared.queue.lock().expect("pool lock");
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        if let Some(mut sup) = self.supervision.take() {
            sup.board.close();
            if let Some(h) = sup.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Armed drop guard: a worker that unwinds out of its loop (a panic
/// *outside* the runner boundary — e.g. lock poisoning) files its death
/// before the thread ends. Disarmed on normal shutdown exit.
struct DeathGuard {
    board: Option<(Arc<DeathBoard>, usize)>,
    armed: bool,
}

impl Drop for DeathGuard {
    fn drop(&mut self) {
        if self.armed {
            if let Some((board, id)) = self.board.as_ref() {
                board.report(*id);
            }
        }
    }
}

fn worker_loop<S, J>(shared: &PoolShared<S, J>, death: Option<(Arc<DeathBoard>, usize)>) {
    let mut guard = DeathGuard { board: death, armed: true };
    loop {
        // Claim the next ready actor (or exit once shut down and dry).
        // A hold gates new claims but never blocks shutdown drain.
        let actor = {
            let mut q = shared.queue.lock().expect("pool lock");
            loop {
                let gated = q.holds > 0 && !q.shutdown;
                if !gated {
                    if let Some(a) = q.ready.pop_front() {
                        break a;
                    }
                    if q.shutdown {
                        guard.armed = false;
                        return;
                    }
                }
                q = shared.cv.wait(q).expect("pool lock");
            }
        };
        // Take one job plus the slot out of the actor, so enqueues from
        // producer threads never block on job execution. The `scheduled`
        // flag guarantees this worker owns the actor alone.
        let (job, mut slot) = {
            let mut inner = actor.inner.lock().expect("actor lock");
            let job = inner.jobs.pop_front().expect("scheduled actor has a job");
            let slot = inner.slot.take().expect("scheduled actor has its slot");
            (job, slot)
        };
        // The supervision boundary: a panicking job is counted and
        // contained; `slot` is only borrowed by the closure, so it
        // survives the unwind and the put-back below runs on both
        // paths — the actor can never lose its slot or wedge its
        // `scheduled` flag to a panic.
        if catch_boundary(|| (shared.runner)(job, &mut slot)).is_err() {
            shared.jobs_panicked.fetch_add(1, Ordering::Relaxed);
        }
        shared.jobs_executed.fetch_add(1, Ordering::Relaxed);
        // Put the slot back; one job per turn, re-queue at the tail if
        // work remains (round-robin fairness across all actors).
        let requeue = {
            let mut inner = actor.inner.lock().expect("actor lock");
            inner.slot = Some(slot);
            if inner.jobs.is_empty() {
                inner.scheduled = false;
                false
            } else {
                true
            }
        };
        if requeue {
            let mut q = shared.queue.lock().expect("pool lock");
            q.ready.push_back(actor);
            drop(q);
            shared.cv.notify_one();
        }
    }
}

/// Consume death reports until the board closes: respawn within the
/// budget (the replacement carries its own death guard, so a respawned
/// worker dying re-enters the same path), degrade once it is spent.
/// Respawned handles are joined here, after the board closes — by then
/// the pool's shutdown flag is set, so they exit promptly.
fn supervisor_loop<S, J>(
    shared: &Arc<PoolShared<S, J>>,
    board: &Arc<DeathBoard>,
    cfg: SupervisionConfig,
    degraded: &Arc<AtomicU64>,
    respawns: &Arc<AtomicU64>,
    mut next_id: usize,
) where
    S: Send + 'static,
    J: Send + 'static,
{
    let epoch = std::time::Instant::now();
    let mut budget = RestartBudget::new(cfg.max_respawns, cfg.window_us);
    let mut spawned: Vec<thread::JoinHandle<()>> = Vec::new();
    while let Some(_dead_id) = board.wait_next() {
        let now_us = epoch.elapsed().as_micros() as u64;
        if budget.allow(now_us) {
            let shared = shared.clone();
            let b = board.clone();
            let id = next_id;
            next_id += 1;
            spawned.push(thread::spawn(move || worker_loop(&shared, Some((b, id)))));
            respawns.fetch_add(1, Ordering::Relaxed);
        } else {
            degraded.store(1, Ordering::Relaxed);
        }
    }
    for h in spawned {
        let _ = h.join();
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::util::sync::chan;
    use std::sync::Mutex as StdMutex;

    /// Record-everything runner: slot is a label, jobs append
    /// (label, job) to a shared log.
    fn logging_pool(
        workers: usize,
    ) -> (ActorPool<u32, u32>, Arc<StdMutex<Vec<(u32, u32)>>>) {
        let log = Arc::new(StdMutex::new(Vec::new()));
        let l = log.clone();
        let pool = ActorPool::new(workers, move |job, slot: &mut u32| {
            l.lock().expect("log lock").push((*slot, job));
        });
        (pool, log)
    }

    #[test]
    fn per_actor_fifo_order() {
        let (pool, log) = logging_pool(4);
        let a = pool.spawn_actor(7);
        for k in 0..50 {
            pool.enqueue(&a, k);
        }
        pool.shutdown();
        let got: Vec<u32> = log.lock().expect("log lock").iter().map(|&(_, j)| j).collect();
        assert_eq!(got, (0..50).collect::<Vec<u32>>(), "FIFO within one actor");
    }

    #[test]
    fn shutdown_drains_every_job_across_actors() {
        let (pool, log) = logging_pool(3);
        let actors: Vec<_> = (0..5u32).map(|s| pool.spawn_actor(s)).collect();
        for (s, a) in actors.iter().enumerate() {
            for k in 0..20u32 {
                pool.enqueue(a, s as u32 * 100 + k);
            }
        }
        pool.shutdown();
        let log = log.lock().expect("log lock");
        assert_eq!(log.len(), 100, "no job lost");
        for s in 0..5u32 {
            let per: Vec<u32> =
                log.iter().filter(|&&(slot, _)| slot == s).map(|&(_, j)| j).collect();
            assert_eq!(per, (0..20).map(|k| s * 100 + k).collect::<Vec<u32>>());
        }
    }

    #[test]
    fn hold_gates_execution_then_release_drains() {
        let (pool, log) = logging_pool(2);
        let a = pool.spawn_actor(0);
        let hold = pool.hold();
        // Give workers a chance to (incorrectly) pick the job up.
        pool.enqueue(&a, 1);
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(pool.jobs_executed(), 0, "held pool must not start jobs");
        assert_eq!(log.lock().expect("log lock").len(), 0);
        drop(hold);
        pool.shutdown();
        assert_eq!(log.lock().expect("log lock").len(), 1, "release must drain");
    }

    #[test]
    fn shutdown_drains_even_while_held() {
        let (pool, log) = logging_pool(1);
        let a = pool.spawn_actor(0);
        let _hold = pool.hold();
        pool.enqueue(&a, 9);
        pool.shutdown();
        assert_eq!(log.lock().expect("log lock").len(), 1);
    }

    #[test]
    fn slot_checked_out_never_blocks_enqueue() {
        // Runner blocks on a rendezvous; enqueue from the main thread
        // must complete while the job is mid-execution.
        let (gate_tx, gate_rx) = chan::bounded::<()>(3);
        let gate_rx = StdMutex::new(gate_rx);
        let pool: ActorPool<(), u32> = ActorPool::new(1, move |_job, _slot| {
            let _ = gate_rx.lock().expect("gate lock").recv();
        });
        let a = pool.spawn_actor(());
        pool.enqueue(&a, 0);
        // Worker is (or will be) parked inside job 0; these must not block.
        pool.enqueue(&a, 1);
        pool.enqueue(&a, 2);
        gate_tx.send(()).expect("gate");
        gate_tx.send(()).expect("gate");
        gate_tx.send(()).expect("gate");
        pool.shutdown();
    }

    /// Regression (fleet supervision PR): a job panicking mid-run used
    /// to unwind past the slot put-back — the slot was lost, the
    /// actor's `scheduled` flag stayed set forever (silently starving
    /// the band), and `shutdown` then panicked joining the dead
    /// worker. With the boundary in place the worker survives, the
    /// slot is preserved, later jobs on the same actor still run, and
    /// shutdown completes cleanly.
    #[test]
    fn panicking_job_cannot_wedge_actor_or_lose_slot() {
        let log = Arc::new(StdMutex::new(Vec::new()));
        let l = log.clone();
        let pool = ActorPool::new(1, move |job: u32, slot: &mut u32| {
            if job == 1 {
                panic!("forced mid-job abort");
            }
            *slot += 1;
            l.lock().expect("log lock").push((*slot, job));
        });
        let a = pool.spawn_actor(0u32);
        pool.enqueue(&a, 0);
        pool.enqueue(&a, 1); // panics
        pool.enqueue(&a, 2); // must still run — FIFO flag must not wedge
        pool.enqueue(&a, 3);
        for _ in 0..2_000 {
            if pool.jobs_executed() == 4 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(pool.jobs_executed(), 4, "jobs after the panic never ran");
        assert_eq!(pool.jobs_panicked(), 1);
        pool.shutdown(); // must not panic on a dead worker's handle
        let got: Vec<(u32, u32)> = log.lock().expect("log lock").clone();
        // Slot survived the unwind: increments continue from 1, and the
        // panicking job left no partial increment.
        assert_eq!(got, vec![(1, 0), (2, 2), (3, 3)]);
    }

    #[test]
    fn supervised_pool_respawns_within_budget_then_degrades() {
        let pool: ActorPool<(), u32> = ActorPool::with_supervision(
            2,
            SupervisionConfig { max_respawns: 2, window_us: 60_000_000 },
            |_job, _slot| {},
        );
        let board = pool.death_board().expect("supervised pool has a board");
        // Two reported deaths fit the budget; the third exceeds it.
        board.report(0);
        board.report(1);
        board.report(7);
        for _ in 0..2_000 {
            if pool.worker_respawns() == 2 && pool.degraded() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(pool.worker_respawns(), 2, "each death respawns at most once");
        assert!(pool.degraded(), "spent budget must raise the degraded flag");
        // The pool still serves jobs end to end.
        let a = pool.spawn_actor(());
        for k in 0..10 {
            pool.enqueue(&a, k);
        }
        pool.shutdown();
    }

    #[test]
    fn restart_budget_is_a_sliding_window() {
        let mut b = RestartBudget::new(2, 1_000);
        assert!(b.allow(0));
        assert!(b.allow(10));
        assert!(!b.allow(20), "third respawn inside the window must be denied");
        // Once the first grant ages out of the window, capacity returns.
        assert!(b.allow(1_005));
        assert!(!b.allow(1_006), "grant at t=10 still inside [6, 1006)");
        assert!(b.allow(1_500));
    }

    #[test]
    fn death_board_close_wakes_waiter_and_drains_pending() {
        let board = Arc::new(DeathBoard::new());
        board.report(3);
        board.close();
        // Pending deaths stay consumable after close; then None.
        assert_eq!(board.wait_next(), Some(3));
        assert_eq!(board.wait_next(), None);
        // A parked waiter is woken by close.
        let b2 = Arc::new(DeathBoard::new());
        let b3 = b2.clone();
        let h = std::thread::spawn(move || b3.wait_next());
        std::thread::sleep(std::time::Duration::from_millis(10));
        b2.close();
        assert_eq!(h.join().expect("join"), None);
    }

    #[test]
    fn counters_track_executed_jobs() {
        let (pool, log) = logging_pool(2);
        let a = pool.spawn_actor(0);
        let b = pool.spawn_actor(1);
        for k in 0..10 {
            pool.enqueue(&a, k);
            pool.enqueue(&b, k);
        }
        // The counter converges to the full job count (poll: workers
        // drain asynchronously; shutdown would consume the pool).
        for _ in 0..2_000 {
            if pool.jobs_executed() == 20 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(pool.jobs_executed(), 20);
        assert_eq!(pool.ready_depth(), 0, "drained pool has no ready actors");
        pool.shutdown();
        assert_eq!(log.lock().expect("log lock").len(), 20);
    }
}
