//! The generic (actor, job) worker pool behind the serve scheduler.
//!
//! A fixed-size thread pool executes jobs queued on per-actor FIFOs.
//! This module is deliberately free of any band/session semantics — it
//! is the pure scheduling core extracted from `serve::scheduler` so the
//! loom models in `tests/loom_sched.rs` can model-check the **actual**
//! production queue logic with trivial slots and jobs. The serve layer
//! instantiates it with `(BandSlot, Job)` and supplies the job runner.
//!
//! ## Invariants (model-checked under `--cfg loom`)
//!
//! * **At most once scheduled** — an actor sits in the global ready
//!   queue at most once (`scheduled` flag), and is processed by at most
//!   one worker at a time; jobs on one actor can never run concurrently
//!   or out of order.
//! * **Per-actor FIFO** — jobs execute strictly in enqueue order; a job
//!   queued before another on the same actor is observed by it.
//! * **One job per turn** — a worker runs one job, then re-queues the
//!   actor at the ready-queue tail if work remains: round-robin
//!   fairness across every actor with pending jobs.
//! * **No lost wakeups** — every enqueue that transitions an actor to
//!   scheduled signals the pool condvar; parked workers always observe
//!   shutdown and hold-release transitions.
//! * **Drain quiescence** — while a [`Hold`] is live, no *new* job
//!   starts (workers finish their current job, then idle); dropping the
//!   last hold resumes draining, and `shutdown` drains every queued job
//!   even while held.
//!
//! The runner executes with the actor's slot checked out of the actor
//! lock, so producers enqueue without ever blocking on job execution.

use crate::util::sync::{thread, Arc, AtomicU64, Condvar, Mutex, Ordering};
use std::collections::VecDeque;

/// One actor: a FIFO of jobs plus a slot of actor-local state handed to
/// the runner with every job.
pub struct Actor<S, J> {
    inner: Mutex<ActorInner<S, J>>,
}

struct ActorInner<S, J> {
    jobs: VecDeque<J>,
    /// True while the actor sits in the ready queue or on a worker.
    scheduled: bool,
    /// None only while a worker has the slot checked out.
    slot: Option<S>,
}

struct ReadyQueue<S, J> {
    ready: VecDeque<Arc<Actor<S, J>>>,
    /// Outstanding [`Hold`]s: workers idle while > 0 (drain gate).
    holds: usize,
    shutdown: bool,
}

type Runner<S, J> = dyn Fn(J, &mut S) + Send + Sync;

struct PoolShared<S, J> {
    queue: Mutex<ReadyQueue<S, J>>,
    cv: Condvar,
    jobs_executed: AtomicU64,
    runner: Box<Runner<S, J>>,
}

/// The fixed worker fleet. See the module docs for the invariants.
pub struct ActorPool<S, J> {
    shared: Arc<PoolShared<S, J>>,
    handles: Vec<thread::JoinHandle<()>>,
}

/// Pauses the pool while alive: workers finish their current job, then
/// idle; dropping the last outstanding hold resumes draining.
pub struct Hold<S, J> {
    shared: Arc<PoolShared<S, J>>,
}

impl<S, J> Drop for Hold<S, J> {
    fn drop(&mut self) {
        let mut q = self.shared.queue.lock().expect("pool lock");
        q.holds -= 1;
        if q.holds == 0 {
            self.shared.cv.notify_all();
        }
    }
}

impl<S: Send + 'static, J: Send + 'static> ActorPool<S, J> {
    /// Spawn `workers.max(1)` worker threads executing jobs through
    /// `runner`. The runner receives each job together with the owning
    /// actor's slot; it runs outside every pool lock.
    pub fn new<F>(workers: usize, runner: F) -> Self
    where
        F: Fn(J, &mut S) + Send + Sync + 'static,
    {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(ReadyQueue { ready: VecDeque::new(), holds: 0, shutdown: false }),
            cv: Condvar::new(),
            jobs_executed: AtomicU64::new(0),
            runner: Box::new(runner),
        });
        let handles = (0..workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Self { shared, handles }
    }

    /// Worker-thread count (fixed at construction).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Register a new actor owning `slot`.
    pub fn spawn_actor(&self, slot: S) -> Arc<Actor<S, J>> {
        Arc::new(Actor {
            inner: Mutex::new(ActorInner {
                jobs: VecDeque::new(),
                scheduled: false,
                slot: Some(slot),
            }),
        })
    }

    /// Enqueue `job` on `actor`'s FIFO; schedules the actor if idle.
    /// Never blocks on job execution — bound the *number* of queued
    /// jobs at the producer (admission control), not here.
    pub fn enqueue(&self, actor: &Arc<Actor<S, J>>, job: J) {
        let newly_scheduled = {
            let mut inner = actor.inner.lock().expect("actor lock");
            inner.jobs.push_back(job);
            if inner.scheduled {
                false
            } else {
                inner.scheduled = true;
                true
            }
        };
        if newly_scheduled {
            let mut q = self.shared.queue.lock().expect("pool lock");
            q.ready.push_back(actor.clone());
            drop(q);
            self.shared.cv.notify_one();
        }
    }

    /// Jobs executed pool-wide since construction.
    pub fn jobs_executed(&self) -> u64 {
        self.shared.jobs_executed.load(Ordering::Relaxed)
    }

    /// Actors currently waiting in the global ready queue.
    pub fn ready_depth(&self) -> usize {
        self.shared.queue.lock().expect("pool lock").ready.len()
    }

    /// Pause draining until the guard drops (see [`Hold`]).
    pub fn hold(&self) -> Hold<S, J> {
        self.shared.queue.lock().expect("pool lock").holds += 1;
        Hold { shared: self.shared.clone() }
    }

    /// Stop the pool: workers drain every queued job (holds included),
    /// then exit.
    pub fn shutdown(mut self) {
        {
            let mut q = self.shared.queue.lock().expect("pool lock");
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            h.join().expect("join worker");
        }
    }
}

fn worker_loop<S, J>(shared: &PoolShared<S, J>) {
    loop {
        // Claim the next ready actor (or exit once shut down and dry).
        // A hold gates new claims but never blocks shutdown drain.
        let actor = {
            let mut q = shared.queue.lock().expect("pool lock");
            loop {
                let gated = q.holds > 0 && !q.shutdown;
                if !gated {
                    if let Some(a) = q.ready.pop_front() {
                        break a;
                    }
                    if q.shutdown {
                        return;
                    }
                }
                q = shared.cv.wait(q).expect("pool lock");
            }
        };
        // Take one job plus the slot out of the actor, so enqueues from
        // producer threads never block on job execution. The `scheduled`
        // flag guarantees this worker owns the actor alone.
        let (job, mut slot) = {
            let mut inner = actor.inner.lock().expect("actor lock");
            let job = inner.jobs.pop_front().expect("scheduled actor has a job");
            let slot = inner.slot.take().expect("scheduled actor has its slot");
            (job, slot)
        };
        (shared.runner)(job, &mut slot);
        shared.jobs_executed.fetch_add(1, Ordering::Relaxed);
        // Put the slot back; one job per turn, re-queue at the tail if
        // work remains (round-robin fairness across all actors).
        let requeue = {
            let mut inner = actor.inner.lock().expect("actor lock");
            inner.slot = Some(slot);
            if inner.jobs.is_empty() {
                inner.scheduled = false;
                false
            } else {
                true
            }
        };
        if requeue {
            let mut q = shared.queue.lock().expect("pool lock");
            q.ready.push_back(actor);
            drop(q);
            shared.cv.notify_one();
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::util::sync::chan;
    use std::sync::Mutex as StdMutex;

    /// Record-everything runner: slot is a label, jobs append
    /// (label, job) to a shared log.
    fn logging_pool(
        workers: usize,
    ) -> (ActorPool<u32, u32>, Arc<StdMutex<Vec<(u32, u32)>>>) {
        let log = Arc::new(StdMutex::new(Vec::new()));
        let l = log.clone();
        let pool = ActorPool::new(workers, move |job, slot: &mut u32| {
            l.lock().expect("log lock").push((*slot, job));
        });
        (pool, log)
    }

    #[test]
    fn per_actor_fifo_order() {
        let (pool, log) = logging_pool(4);
        let a = pool.spawn_actor(7);
        for k in 0..50 {
            pool.enqueue(&a, k);
        }
        pool.shutdown();
        let got: Vec<u32> = log.lock().expect("log lock").iter().map(|&(_, j)| j).collect();
        assert_eq!(got, (0..50).collect::<Vec<u32>>(), "FIFO within one actor");
    }

    #[test]
    fn shutdown_drains_every_job_across_actors() {
        let (pool, log) = logging_pool(3);
        let actors: Vec<_> = (0..5u32).map(|s| pool.spawn_actor(s)).collect();
        for (s, a) in actors.iter().enumerate() {
            for k in 0..20u32 {
                pool.enqueue(a, s as u32 * 100 + k);
            }
        }
        pool.shutdown();
        let log = log.lock().expect("log lock");
        assert_eq!(log.len(), 100, "no job lost");
        for s in 0..5u32 {
            let per: Vec<u32> =
                log.iter().filter(|&&(slot, _)| slot == s).map(|&(_, j)| j).collect();
            assert_eq!(per, (0..20).map(|k| s * 100 + k).collect::<Vec<u32>>());
        }
    }

    #[test]
    fn hold_gates_execution_then_release_drains() {
        let (pool, log) = logging_pool(2);
        let a = pool.spawn_actor(0);
        let hold = pool.hold();
        // Give workers a chance to (incorrectly) pick the job up.
        pool.enqueue(&a, 1);
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(pool.jobs_executed(), 0, "held pool must not start jobs");
        assert_eq!(log.lock().expect("log lock").len(), 0);
        drop(hold);
        pool.shutdown();
        assert_eq!(log.lock().expect("log lock").len(), 1, "release must drain");
    }

    #[test]
    fn shutdown_drains_even_while_held() {
        let (pool, log) = logging_pool(1);
        let a = pool.spawn_actor(0);
        let _hold = pool.hold();
        pool.enqueue(&a, 9);
        pool.shutdown();
        assert_eq!(log.lock().expect("log lock").len(), 1);
    }

    #[test]
    fn slot_checked_out_never_blocks_enqueue() {
        // Runner blocks on a rendezvous; enqueue from the main thread
        // must complete while the job is mid-execution.
        let (gate_tx, gate_rx) = chan::bounded::<()>(3);
        let gate_rx = StdMutex::new(gate_rx);
        let pool: ActorPool<(), u32> = ActorPool::new(1, move |_job, _slot| {
            let _ = gate_rx.lock().expect("gate lock").recv();
        });
        let a = pool.spawn_actor(());
        pool.enqueue(&a, 0);
        // Worker is (or will be) parked inside job 0; these must not block.
        pool.enqueue(&a, 1);
        pool.enqueue(&a, 2);
        gate_tx.send(()).expect("gate");
        gate_tx.send(()).expect("gate");
        gate_tx.send(()).expect("gate");
        pool.shutdown();
    }

    #[test]
    fn counters_track_executed_jobs() {
        let (pool, log) = logging_pool(2);
        let a = pool.spawn_actor(0);
        let b = pool.spawn_actor(1);
        for k in 0..10 {
            pool.enqueue(&a, k);
            pool.enqueue(&b, k);
        }
        // The counter converges to the full job count (poll: workers
        // drain asynchronously; shutdown would consume the pool).
        for _ in 0..2_000 {
            if pool.jobs_executed() == 20 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(pool.jobs_executed(), 20);
        assert_eq!(pool.ready_depth(), 0, "drained pool has no ready actors");
        pool.shutdown();
        assert_eq!(log.lock().expect("log lock").len(), 20);
    }
}
