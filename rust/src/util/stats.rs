//! Small statistics helpers shared by the Monte Carlo, metrics and bench code.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Coefficient of variation in percent: 100·σ/μ. This is the statistic the
/// paper reports for the Monte Carlo mismatch analysis (Fig. 5b).
pub fn cv_percent(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return f64::NAN;
    }
    100.0 * stddev(xs) / m.abs()
}

/// p-th percentile (linear interpolation), p in [0, 100]. Empty input
/// yields 0.0 so latency gauges over idle rings read as zero rather
/// than panicking mid-serve.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (p / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min/max as a tuple, NaN-free inputs assumed.
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
        (lo.min(x), hi.max(x))
    })
}

/// Mean squared error between two equal-length series.
pub fn mse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() / a.len() as f64
}

/// Fixed-width histogram over [lo, hi); returns per-bin counts. Values
/// outside the range are clamped into the edge bins so event-time
/// distributions with long tails (Fig. 4d) stay visible.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<u64> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0u64; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let mut b = ((x - lo) / w).floor() as i64;
        b = b.clamp(0, bins as i64 - 1);
        h[b as usize] += 1;
    }
    h
}

/// Simple linear regression y = a + b·x; returns (a, b, r²).
pub fn linreg(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let ss_res: f64 = x
        .iter()
        .zip(y)
        .map(|(xi, yi)| {
            let e = yi - (a + b * xi);
            e * e
        })
        .sum();
    let ss_tot: f64 = y.iter().map(|yi| (yi - my) * (yi - my)).sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    let _ = n;
    (a, b, r2)
}

/// Running-statistics accumulator (Welford) for streaming benches.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn cv_matches_hand_calc() {
        let xs = [9.0, 10.0, 11.0];
        // σ = sqrt(2/3), μ = 10 → CV = 8.1649%
        assert!((cv_percent(&xs) - 8.16496580927726).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn percentile_empty_ring_reads_zero() {
        // An idle latency ring must gauge as 0, not panic (serve layer
        // polls p50/p99 before the first batch completes).
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 99.0), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn percentile_single_sample_is_every_percentile() {
        let xs = [7.25];
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&xs, p), 7.25);
        }
    }

    #[test]
    fn p99_on_tiny_rings_interpolates_toward_max() {
        // Two samples: p99 sits 99% of the way to the max.
        let xs = [0.0, 100.0];
        assert!((percentile(&xs, 99.0) - 99.0).abs() < 1e-12);
        // Three samples: pos = 1.98 → between v[1] and v[2].
        let xs = [10.0, 20.0, 30.0];
        assert!((percentile(&xs, 99.0) - 29.8).abs() < 1e-12);
        // p99 never exceeds the max on any tiny ring.
        for n in 1..=8usize {
            let v: Vec<f64> = (0..n).map(|i| i as f64).collect();
            assert!(percentile(&v, 99.0) <= (n - 1) as f64);
        }
    }

    #[test]
    fn percentile_sorts_unsorted_input() {
        let xs = [5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn histogram_clamps_edges() {
        let h = histogram(&[-5.0, 0.1, 0.9, 99.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
    }

    #[test]
    fn linreg_exact_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linreg(&x, &y);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn running_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - mean(&xs)).abs() < 1e-12);
        assert!((r.variance() - variance(&xs)).abs() < 1e-9);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn mse_zero_for_identical() {
        let a = [1.0, 2.0];
        assert_eq!(mse(&a, &a), 0.0);
    }
}
