//! Frame resampling helpers (the "interpolation to 224×224" step of the
//! paper's Sec. IV-D, at our 32×32/64×64 model geometries).

use super::grid::Grid;

/// Bilinear resize to (new_w, new_h).
pub fn resize_bilinear(src: &Grid<f64>, new_w: usize, new_h: usize) -> Grid<f64> {
    assert!(new_w > 0 && new_h > 0);
    let (w, h) = (src.width(), src.height());
    if w == new_w && h == new_h {
        return src.clone();
    }
    Grid::from_fn(new_w, new_h, |x, y| {
        // Map output pixel centers into source coordinates.
        let sx = (x as f64 + 0.5) * w as f64 / new_w as f64 - 0.5;
        let sy = (y as f64 + 0.5) * h as f64 / new_h as f64 - 0.5;
        let x0 = sx.floor().clamp(0.0, (w - 1) as f64) as usize;
        let y0 = sy.floor().clamp(0.0, (h - 1) as f64) as usize;
        let x1 = (x0 + 1).min(w - 1);
        let y1 = (y0 + 1).min(h - 1);
        let fx = (sx - x0 as f64).clamp(0.0, 1.0);
        let fy = (sy - y0 as f64).clamp(0.0, 1.0);
        src.get(x0, y0) * (1.0 - fx) * (1.0 - fy)
            + src.get(x1, y0) * fx * (1.0 - fy)
            + src.get(x0, y1) * (1.0 - fx) * fy
            + src.get(x1, y1) * fx * fy
    })
}

/// Center-crop (or zero-pad) to (new_w, new_h) without rescaling.
pub fn center_fit(src: &Grid<f64>, new_w: usize, new_h: usize) -> Grid<f64> {
    let (w, h) = (src.width(), src.height());
    let ox = (new_w as i64 - w as i64) / 2;
    let oy = (new_h as i64 - h as i64) / 2;
    Grid::from_fn(new_w, new_h, |x, y| {
        let sx = x as i64 - ox;
        let sy = y as i64 - oy;
        src.get_checked(sx, sy).copied().unwrap_or(0.0)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_resize_is_noop() {
        let g = Grid::from_fn(5, 4, |x, y| (x * y) as f64);
        assert_eq!(resize_bilinear(&g, 5, 4), g);
    }

    #[test]
    fn constant_image_stays_constant() {
        let g = Grid::new(7, 9, 0.37);
        let r = resize_bilinear(&g, 13, 5);
        for &v in r.as_slice() {
            assert!((v - 0.37).abs() < 1e-12);
        }
    }

    #[test]
    fn upsample_preserves_gradient_direction() {
        let g = Grid::from_fn(4, 4, |x, _| x as f64);
        let r = resize_bilinear(&g, 8, 8);
        for y in 0..8 {
            for x in 1..8 {
                assert!(r.get(x, y) >= r.get(x - 1, y));
            }
        }
    }

    #[test]
    fn range_preserved() {
        let g = Grid::from_fn(10, 10, |x, y| ((x + y) % 2) as f64);
        let r = resize_bilinear(&g, 3, 3);
        for &v in r.as_slice() {
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn center_fit_pads_and_crops() {
        let g = Grid::new(2, 2, 1.0);
        let padded = center_fit(&g, 4, 4);
        assert_eq!(*padded.get(0, 0), 0.0);
        assert_eq!(*padded.get(1, 1), 1.0);
        let cropped = center_fit(&padded, 2, 2);
        for &v in cropped.as_slice() {
            assert_eq!(v, 1.0);
        }
    }
}
