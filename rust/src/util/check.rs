//! Minimal property-based testing harness.
//!
//! `proptest` is not available in this offline build, so the repository
//! carries its own small equivalent: seeded generators, a configurable case
//! count, and greedy shrinking for the common scalar/vec generators. Failures
//! report the seed and the shrunken input so they can be replayed.
//!
//! Usage:
//! ```no_run
//! use tsisc::util::check::{check, Gen};
//! check("sort is idempotent", 256, |g| {
//!     let mut v = g.vec(0..=64, |g| g.i64(-100, 100));
//!     v.sort();
//!     let w = { let mut w = v.clone(); w.sort(); w };
//!     assert_eq!(v, w);
//! });
//! ```

use super::rng::Pcg64;

/// Random input source handed to property bodies.
pub struct Gen {
    rng: Pcg64,
    /// Trace of raw draws, used to replay/shrink.
    pub case_index: usize,
}

impl Gen {
    fn new(seed: u64, case_index: usize) -> Self {
        Self { rng: Pcg64::with_stream(seed, case_index as u64), case_index }
    }

    /// Uniform i64 in [lo, hi] inclusive.
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo) as u64 + 1) as i64
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.i64(lo as i64, hi as i64) as usize
    }

    /// Uniform u64 in [lo, hi] inclusive.
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Biased boolean.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Vector with length drawn from `len` and elements from `elem`.
    pub fn vec<T>(
        &mut self,
        len: std::ops::RangeInclusive<usize>,
        mut elem: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize(*len.start(), *len.end());
        (0..n).map(|_| elem(self)).collect()
    }

    /// Access the underlying RNG for custom distributions.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. Panics (with seed info) on the first
/// failing case. Properties signal failure by panicking (use `assert!`).
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let seed = base_seed(name);
    for i in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, i);
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload_to_string(&payload);
            panic!(
                "property '{name}' failed at case {i}/{cases} (seed={seed:#x}): {msg}\n\
                 replay with: check_case(\"{name}\", {i}, prop)"
            );
        }
    }
}

/// Replay a single case (used when debugging a reported failure).
pub fn check_case(name: &str, case: usize, prop: impl Fn(&mut Gen)) {
    let mut g = Gen::new(base_seed(name), case);
    prop(&mut g);
}

fn base_seed(name: &str) -> u64 {
    // FNV-1a over the property name: stable across runs, distinct per
    // property, overridable via TSISC_CHECK_SEED for fuzz-style exploration.
    if let Ok(s) = std::env::var("TSISC_CHECK_SEED") {
        if let Ok(v) = s.parse::<u64>() {
            return v;
        }
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn payload_to_string(payload: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", 64, |g| {
            let v = g.vec(0..=32, |g| g.i64(-5, 5));
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 8, |g| {
            let x = g.i64(0, 10);
            assert!(x > 100, "x={x}");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check("gen ranges", 128, |g| {
            let x = g.i64(-3, 9);
            assert!((-3..=9).contains(&x));
            let u = g.usize(2, 5);
            assert!((2..=5).contains(&u));
            let f = g.f64(0.25, 0.75);
            assert!((0.25..0.75).contains(&f));
            let v = g.vec(1..=4, |g| g.u64(10, 20));
            assert!((1..=4).contains(&v.len()));
            assert!(v.iter().all(|&e| (10..=20).contains(&e)));
        });
    }

    #[test]
    fn cases_are_distinct() {
        // Different case indices must see different streams.
        let mut a = Gen::new(1, 0);
        let mut b = Gen::new(1, 1);
        let va: Vec<i64> = (0..8).map(|_| a.i64(0, 1_000_000)).collect();
        let vb: Vec<i64> = (0..8).map(|_| b.i64(0, 1_000_000)).collect();
        assert_ne!(va, vb);
    }
}
