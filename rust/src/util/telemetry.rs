//! Lock-light metrics: typed atomic counters/gauges, fixed log2-bucket
//! latency histograms, and a named [`Registry`] with Prometheus-style
//! text exposition.
//!
//! This is the measurement substrate the serve fleet reads and exports
//! (`serve::obs`). Design laws:
//!
//! * **Loom-safe.** Every primitive goes through the [`super::sync`]
//!   facade, so telemetry inside loom-modeled code compiles under
//!   `--cfg loom` like everything else in the concurrency stack.
//! * **Zero allocation on the hot path.** [`Counter::inc`],
//!   [`Gauge::set`] and [`Histogram::record`] are a handful of relaxed
//!   atomic ops on pre-sized storage; strings and `Vec`s only appear at
//!   registration and render time.
//! * **Counters and gauges are always real.** Several "metrics" double
//!   as functional state (admission control reads queue depth, the
//!   degrade ladder reads resident bytes, drain accounting balances
//!   event counts), so compiling them out would change behavior.
//!   Only the purely observational parts — [`Histogram`] and the
//!   flight recorder in `serve::obs` — compile to proven-zero-cost
//!   no-ops under the `telemetry-off` feature.
//! * **Mergeable.** Histograms with fixed log2 buckets merge by bucket
//!   addition, which is associative and loses nothing beyond the bucket
//!   quantization each sample already paid — so per-band, per-session
//!   and fleet views are all the same type.
//!
//! Metric names are part of the operational interface and follow the
//! repo law checked by `cargo xtask lint-invariants` (`telemetry-naming`):
//! `^[a-z0-9_]+(_total|_us|_bytes|_ratio)$` — see [`valid_metric_name`].
//! All durations are **microseconds** (`_us`), repo-wide.

use super::sync::{Arc, AtomicU64, Mutex, Ordering};

/// A monotonically increasing event count (`_total` metrics).
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self { v: AtomicU64::new(0) }
    }

    /// Count one event.
    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n` events.
    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Current count.
    #[inline]
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// A point-in-time level (`_bytes`, depth-style metrics): settable,
/// unlike a [`Counter`].
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Self { v: AtomicU64::new(0) }
    }

    /// Set the level.
    #[inline]
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    /// Current level.
    #[inline]
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Number of log2 buckets a [`Histogram`] carries. Bucket 0 holds the
/// value 0; bucket `i` (1 ≤ i < 31) holds `[2^(i-1), 2^i - 1]`; the
/// last bucket holds everything ≥ 2^30. In microseconds that spans
/// sub-µs to ~18 minutes — every latency the fleet can plausibly see.
pub const HIST_BUCKETS: usize = 32;

/// Upper bound of bucket `i` — the value percentile queries report for
/// samples landing in it (conservative: never under-reports).
#[inline]
pub fn bucket_upper(i: usize) -> u64 {
    match i {
        0 => 0,
        _ if i >= HIST_BUCKETS - 1 => u64::MAX,
        _ => (1u64 << i) - 1,
    }
}

/// The log2 bucket a value lands in.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

/// A fixed log2-bucket latency histogram (microsecond samples by
/// convention). Recording is a few relaxed atomic adds — no locks, no
/// allocation; merging is bucket-wise addition (associative). Under the
/// `telemetry-off` feature this type is a zero-sized no-op whose
/// zero cost is proven by `size_of` in the tests.
#[cfg(not(feature = "telemetry-off"))]
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

#[cfg(not(feature = "telemetry-off"))]
impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(not(feature = "telemetry-off"))]
impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one sample (µs).
    #[inline]
    pub fn record(&self, v_us: u64) {
        self.buckets[bucket_index(v_us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v_us, Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples (µs).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Fold `other`'s samples into `self` (bucket-wise addition —
    /// associative and commutative, so per-band → per-session → fleet
    /// aggregation order never matters).
    pub fn merge(&self, other: &Histogram) {
        for (b, o) in self.buckets.iter().zip(&other.buckets) {
            b.fetch_add(o.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Nearest-rank percentile (`p` in (0, 100]), reported as the upper
    /// bound of the bucket the rank falls in — bucket-exact: equal to
    /// `bucket_upper(bucket_index(v))` of the true sorted-reference
    /// percentile value `v` (asserted in `tests/telemetry_equiv.rs`).
    /// 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((p / 100.0 * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for i in 0..HIST_BUCKETS {
            seen += self.buckets[i].load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_upper(i);
            }
        }
        bucket_upper(HIST_BUCKETS - 1)
    }

    /// Raw bucket counts (snapshot; for exposition and tests).
    pub fn bucket_counts(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }
}

/// The `telemetry-off` no-op sink: zero-sized, every method compiles to
/// nothing. Counters and gauges stay real (they are functional state —
/// see the module docs); only the purely observational histogram
/// drops out.
#[cfg(feature = "telemetry-off")]
#[derive(Debug, Default)]
pub struct Histogram;

#[cfg(feature = "telemetry-off")]
impl Histogram {
    pub fn new() -> Self {
        Histogram
    }

    #[inline]
    pub fn record(&self, _v_us: u64) {}

    pub fn count(&self) -> u64 {
        0
    }

    pub fn sum(&self) -> u64 {
        0
    }

    pub fn merge(&self, _other: &Histogram) {}

    pub fn percentile(&self, _p: f64) -> u64 {
        0
    }

    pub fn bucket_counts(&self) -> [u64; HIST_BUCKETS] {
        [0; HIST_BUCKETS]
    }
}

/// The repo's metric-name law (also enforced mechanically by the
/// `telemetry-naming` xtask lint over registration sites):
/// `^[a-z0-9_]+(_total|_us|_bytes|_ratio)$` — lowercase snake_case with
/// a unit/kind suffix, so every exported name is self-describing
/// (counters `_total`, durations `_us`, sizes `_bytes`, fractions
/// `_ratio`).
pub fn valid_metric_name(name: &str) -> bool {
    let chars_ok =
        name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_');
    let suffix_ok = ["_total", "_us", "_bytes", "_ratio"]
        .iter()
        .any(|s| name.len() > s.len() && name.ends_with(s));
    !name.is_empty() && chars_ok && suffix_ok
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// A named metric registry: registration is idempotent per name (the
/// second `counter("x_total")` returns the first's handle), names obey
/// [`valid_metric_name`] (checked at registration), and [`Registry::render`]
/// emits the whole contents as Prometheus-style text. Registration
/// takes a short lock; reads and writes of the handed-out metrics are
/// lock-free.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Vec<(String, Metric)>>,
}

impl Registry {
    pub fn new() -> Self {
        Self { inner: Mutex::new(Vec::new()) }
    }

    fn slot<T, F: FnOnce() -> Metric, G: Fn(&Metric) -> Option<Arc<T>>>(
        &self,
        name: &str,
        make: F,
        cast: G,
    ) -> Arc<T> {
        debug_assert!(valid_metric_name(name), "bad metric name {name:?}");
        let mut inner = self.inner.lock().expect("registry lock");
        if let Some((_, m)) = inner.iter().find(|(n, _)| n == name) {
            if let Some(h) = cast(m) {
                return h;
            }
            // Same name registered as a different type: a programming
            // error; hand back a fresh unregistered handle rather than
            // panicking in serving code.
            debug_assert!(false, "metric {name:?} re-registered as a different type");
        }
        let metric = make();
        let handle = cast(&metric).expect("freshly made metric casts to its own type");
        inner.push((name.to_string(), metric));
        handle
    }

    /// Register (or fetch) the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.slot(
            name,
            || Metric::Counter(Arc::new(Counter::new())),
            |m| match m {
                Metric::Counter(c) => Some(c.clone()),
                _ => None,
            },
        )
    }

    /// Register (or fetch) the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.slot(
            name,
            || Metric::Gauge(Arc::new(Gauge::new())),
            |m| match m {
                Metric::Gauge(g) => Some(g.clone()),
                _ => None,
            },
        )
    }

    /// Register (or fetch) the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.slot(
            name,
            || Metric::Histogram(Arc::new(Histogram::new())),
            |m| match m {
                Metric::Histogram(h) => Some(h.clone()),
                _ => None,
            },
        )
    }

    /// Registered metric names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().expect("registry lock").iter().map(|(n, _)| n.clone()).collect()
    }

    /// Render every registered metric as Prometheus-style text
    /// exposition: counters and gauges one line each, histograms as
    /// quantile summaries (`{quantile="0.5"|"0.99"}` + `_count` +
    /// `_sum`). This is the body both export surfaces (the `STATS` wire
    /// reply and `tsisc serve --metrics`) serve.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let inner = self.inner.lock().expect("registry lock");
        for (name, metric) in inner.iter() {
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", g.get()));
                }
                Metric::Histogram(h) => {
                    render_histogram(&mut out, name, "", h);
                }
            }
        }
        out
    }
}

/// Append one histogram's summary exposition (`labels` is either empty
/// or a rendered `{key="value"}` block, used by `serve::obs` for
/// per-session lines).
pub fn render_histogram(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    out.push_str(&format!("# TYPE {name} summary\n"));
    out.push_str(&format!("{name}{{quantile=\"0.5\"{labels}}} {}\n", h.percentile(50.0)));
    out.push_str(&format!("{name}{{quantile=\"0.99\"{labels}}} {}\n", h.percentile(99.0)));
    let labels_block =
        if labels.is_empty() { String::new() } else { format!("{{{}}}", &labels[1..]) };
    out.push_str(&format!("{name}_count{labels_block} {}\n", h.count()));
    out.push_str(&format!("{name}_sum{labels_block} {}\n", h.sum()));
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_are_plain_atomics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(42);
        assert_eq!(g.get(), 42);
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn bucket_index_and_upper_are_consistent() {
        // Every value lands in a bucket whose bounds contain it.
        for v in [0u64, 1, 2, 3, 4, 7, 8, 1023, 1024, 1_000_000, u64::MAX] {
            let i = bucket_index(v);
            assert!(v <= bucket_upper(i), "v={v} i={i}");
            if i > 0 {
                assert!(v > bucket_upper(i - 1), "v={v} i={i}");
            }
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(1), 1);
        assert_eq!(bucket_upper(2), 3);
    }

    #[cfg(not(feature = "telemetry-off"))]
    #[test]
    fn histogram_records_and_reports_bucket_uppers() {
        let h = Histogram::new();
        for v in [10u64, 20, 30, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 1060);
        // p50 of [10,20,30,1000]: nearest-rank = 2nd sample (20) →
        // bucket [16,31] upper 31.
        assert_eq!(h.percentile(50.0), 31);
        // p99 → 4th sample (1000) → bucket [512,1023] upper 1023.
        assert_eq!(h.percentile(99.0), 1023);
        assert_eq!(Histogram::new().percentile(99.0), 0, "empty histogram reads 0");
    }

    #[cfg(not(feature = "telemetry-off"))]
    #[test]
    fn histogram_merge_adds_buckets() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(5);
        b.record(500);
        b.record(7);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.sum(), 512);
        let direct = Histogram::new();
        for v in [5u64, 500, 7] {
            direct.record(v);
        }
        assert_eq!(a.bucket_counts(), direct.bucket_counts());
    }

    #[cfg(feature = "telemetry-off")]
    #[test]
    fn telemetry_off_histogram_is_zero_sized_and_silent() {
        // The no-op sink's zero cost, proven: no storage at all.
        assert_eq!(std::mem::size_of::<Histogram>(), 0);
        let h = Histogram::new();
        h.record(123);
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0);
    }

    #[test]
    fn metric_name_law() {
        for ok in ["events_in_total", "queue_wait_us", "resident_bytes", "worker_busy_ratio"] {
            assert!(valid_metric_name(ok), "{ok}");
        }
        for bad in [
            "",
            "_total",              // empty stem
            "EventsIn_total",      // case
            "events-in_total",     // dash
            "events_in",           // no suffix
            "latency_ms",          // wrong unit: µs is the repo law
        ] {
            assert!(!valid_metric_name(bad), "{bad}");
        }
    }

    #[test]
    fn registry_is_idempotent_and_renders_everything() {
        let r = Registry::new();
        let c1 = r.counter("jobs_total");
        let c2 = r.counter("jobs_total");
        c1.inc();
        c2.inc();
        assert_eq!(c1.get(), 2, "same name must return the same counter");
        r.gauge("resident_bytes").set(4096);
        let h = r.histogram("queue_wait_us");
        h.record(100);
        let text = r.render();
        assert!(text.contains("jobs_total 2"));
        assert!(text.contains("resident_bytes 4096"));
        assert!(text.contains("# TYPE queue_wait_us summary"));
        assert!(text.contains("queue_wait_us_count 1") || cfg!(feature = "telemetry-off"));
        assert_eq!(r.names().len(), 3);
    }

    #[test]
    fn labeled_histogram_lines_render() {
        let h = Histogram::new();
        h.record(3);
        let mut out = String::new();
        render_histogram(&mut out, "stage_render_us", ",session=\"s0\"", &h);
        assert!(out.contains("stage_render_us{quantile=\"0.5\",session=\"s0\"}"));
        assert!(out.contains("stage_render_us_count{session=\"s0\"}"));
    }
}
