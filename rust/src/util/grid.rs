//! A small row-major 2D grid used for intensity fields, voltage planes and
//! time-surface frames throughout the simulator.

/// Clamped inclusive patch bounds around `c` with radius `r` in a
/// dimension of size `limit` — shared by every (2r+1)² neighbourhood
/// walk (SITS/TOS updates, the STCF support scan).
#[inline]
pub fn patch_bounds(c: usize, r: usize, limit: usize) -> (usize, usize) {
    (c.saturating_sub(r), (c + r).min(limit - 1))
}

/// Row-major 2D array of `T` with (width, height) addressing `(x, y)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Grid<T> {
    width: usize,
    height: usize,
    data: Vec<T>,
}

impl<T: Clone> Grid<T> {
    /// Grid filled with `fill`.
    pub fn new(width: usize, height: usize, fill: T) -> Self {
        assert!(width > 0 && height > 0, "empty grid");
        Self { width, height, data: vec![fill; width * height] }
    }

    /// Build from a closure of (x, y).
    pub fn from_fn(width: usize, height: usize, mut f: impl FnMut(usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(width * height);
        for y in 0..height {
            for x in 0..width {
                data.push(f(x, y));
            }
        }
        Self { width, height, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(width: usize, height: usize, data: Vec<T>) -> Self {
        assert_eq!(data.len(), width * height);
        Self { width, height, data }
    }

    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }
    #[inline]
    pub fn height(&self) -> usize {
        self.height
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn idx(&self, x: usize, y: usize) -> usize {
        debug_assert!(x < self.width && y < self.height);
        y * self.width + x
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> &T {
        &self.data[self.idx(x, y)]
    }

    #[inline]
    pub fn get_mut(&mut self, x: usize, y: usize) -> &mut T {
        let i = self.idx(x, y);
        &mut self.data[i]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: T) {
        let i = self.idx(x, y);
        self.data[i] = v;
    }

    /// Checked accessor returning None out of bounds (patch iteration).
    #[inline]
    pub fn get_checked(&self, x: i64, y: i64) -> Option<&T> {
        if x < 0 || y < 0 || x as usize >= self.width || y as usize >= self.height {
            None
        } else {
            Some(&self.data[y as usize * self.width + x as usize])
        }
    }

    /// Reshape in place to (width, height), reallocating only on a shape
    /// change. The allocation-free `frame_into` readout path calls this
    /// first, so a warm buffer is never reallocated.
    pub fn ensure_shape(&mut self, width: usize, height: usize, fill: T) {
        if self.width != width || self.height != height {
            *self = Grid::new(width, height, fill);
        }
    }

    /// Overwrite every cell with `fill` (no reallocation).
    pub fn fill(&mut self, fill: T) {
        self.data.fill(fill);
    }

    /// One row as a contiguous slice — the unit of the row-sliced readout
    /// and patch-scan loops (no per-element `y * width + x` math).
    #[inline]
    pub fn row(&self, y: usize) -> &[T] {
        debug_assert!(y < self.height);
        &self.data[y * self.width..(y + 1) * self.width]
    }

    /// Mutable row slice (see [`Grid::row`]).
    #[inline]
    pub fn row_mut(&mut self, y: usize) -> &mut [T] {
        debug_assert!(y < self.height);
        &mut self.data[y * self.width..(y + 1) * self.width]
    }

    /// Disjoint mutable row slabs for chunked (scoped-thread) rendering:
    /// one `&mut [T]` per range, covering rows `r.start..r.end` row-major.
    /// `ranges` must be sorted, non-overlapping and contiguous (each
    /// range starts where the previous ended) — the cover produced by
    /// [`crate::util::parallel::balanced_row_ranges`].
    pub fn row_slabs_mut(&mut self, ranges: &[std::ops::Range<usize>]) -> Vec<&mut [T]> {
        let w = self.width;
        let Some(first) = ranges.first() else {
            return Vec::new();
        };
        assert!(first.start <= self.height);
        let mut rest: &mut [T] = &mut self.data[first.start * w..];
        let mut consumed = first.start;
        let mut slabs = Vec::with_capacity(ranges.len());
        for r in ranges {
            assert!(
                r.start == consumed && r.start < r.end && r.end <= self.height,
                "row ranges must be sorted, contiguous and in bounds"
            );
            let (slab, tail) = rest.split_at_mut((r.end - r.start) * w);
            slabs.push(slab);
            rest = tail;
            consumed = r.end;
        }
        slabs
    }

    /// Resident bytes of this grid (struct + element buffer, using the
    /// buffer's capacity so an over-allocated frame buffer is counted
    /// honestly) — one leaf of the serve layer's `resident_bytes` gauge.
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.data.capacity() * std::mem::size_of::<T>()
    }

    /// Raw row-major slice.
    pub fn as_slice(&self) -> &[T] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Map into a new grid.
    pub fn map<U: Clone>(&self, f: impl Fn(&T) -> U) -> Grid<U> {
        Grid { width: self.width, height: self.height, data: self.data.iter().map(f).collect() }
    }

    /// Iterate (x, y, &value).
    pub fn iter_coords(&self) -> impl Iterator<Item = (usize, usize, &T)> {
        let w = self.width;
        self.data.iter().enumerate().map(move |(i, v)| (i % w, i / w, v))
    }
}

impl Grid<f64> {
    /// Write as a binary-free ASCII PGM (P2) for quick visual inspection.
    /// Values are min-max scaled to 0..255.
    pub fn to_pgm(&self) -> String {
        let (lo, hi) = crate::util::stats::min_max(self.as_slice());
        let scale = if hi > lo { 255.0 / (hi - lo) } else { 0.0 };
        let mut s = format!("P2\n{} {}\n255\n", self.width, self.height);
        for y in 0..self.height {
            let row: Vec<String> = (0..self.width)
                .map(|x| format!("{}", ((self.get(x, y) - lo) * scale).round() as u8))
                .collect();
            s.push_str(&row.join(" "));
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_set_get() {
        let mut g = Grid::new(4, 3, 0i32);
        g.set(2, 1, 7);
        assert_eq!(*g.get(2, 1), 7);
        assert_eq!(*g.get(0, 0), 0);
        assert_eq!(g.idx(3, 2), 11);
    }

    #[test]
    fn from_fn_layout() {
        let g = Grid::from_fn(3, 2, |x, y| (x, y));
        assert_eq!(*g.get(2, 1), (2, 1));
        assert_eq!(g.as_slice()[5], (2, 1)); // row-major
    }

    #[test]
    fn checked_bounds() {
        let g = Grid::new(2, 2, 1u8);
        assert!(g.get_checked(-1, 0).is_none());
        assert!(g.get_checked(0, 2).is_none());
        assert_eq!(g.get_checked(1, 1), Some(&1));
    }

    #[test]
    fn pgm_header() {
        let g = Grid::new(2, 2, 0.5f64);
        let s = g.to_pgm();
        assert!(s.starts_with("P2\n2 2\n255\n"));
    }

    #[test]
    fn ensure_shape_keeps_buffer_when_unchanged() {
        let mut g = Grid::new(4, 3, 1.0f64);
        let ptr = g.as_slice().as_ptr();
        g.ensure_shape(4, 3, 0.0);
        assert_eq!(g.as_slice().as_ptr(), ptr, "same shape must not reallocate");
        assert_eq!(*g.get(0, 0), 1.0, "same shape must not clear");
        g.ensure_shape(2, 2, 0.5);
        assert_eq!(g.width(), 2);
        assert_eq!(*g.get(1, 1), 0.5);
    }

    #[test]
    fn fill_overwrites_all() {
        let mut g = Grid::from_fn(3, 3, |x, y| (x + y) as f64);
        let ptr = g.as_slice().as_ptr();
        g.fill(0.0);
        assert!(g.as_slice().iter().all(|&v| v == 0.0));
        assert_eq!(g.as_slice().as_ptr(), ptr);
    }

    #[test]
    fn row_slices_match_manual_indexing() {
        let mut g = Grid::from_fn(4, 3, |x, y| (y * 4 + x) as i32);
        assert_eq!(g.row(1), &[4, 5, 6, 7]);
        g.row_mut(2)[3] = -1;
        assert_eq!(*g.get(3, 2), -1);
        assert_eq!(g.row(0).len(), g.width());
    }

    #[test]
    fn row_slabs_cover_disjointly() {
        let mut g = Grid::from_fn(3, 5, |x, y| (y * 3 + x) as i32);
        let slabs = g.row_slabs_mut(&[0..2, 2..3, 3..5]);
        assert_eq!(slabs.len(), 3);
        assert_eq!(slabs[0], &[0, 1, 2, 3, 4, 5]);
        assert_eq!(slabs[1], &[6, 7, 8]);
        assert_eq!(slabs[2].len(), 6);
        slabs.into_iter().flatten().for_each(|v| *v = -1);
        assert!(g.as_slice().iter().all(|&v| v == -1));
        assert!(g.row_slabs_mut(&[]).is_empty());
    }

    #[test]
    fn map_preserves_shape() {
        let g = Grid::from_fn(3, 3, |x, y| (x + y) as f64);
        let m = g.map(|v| v * 2.0);
        assert_eq!(m.width(), 3);
        assert_eq!(*m.get(2, 2), 8.0);
    }
}
