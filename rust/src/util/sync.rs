//! Loom-switchable concurrency primitives — the single import point for
//! every lock, condvar, atomic, thread spawn and channel in the
//! concurrency stack ([`crate::serve`], [`crate::coordinator::router`],
//! [`crate::denoise::sharded`], [`crate::util::actor`]).
//!
//! Built normally, everything re-exports `std::sync` / `std::thread`
//! verbatim — zero overhead, zero behavior change. Built with
//! `RUSTFLAGS="--cfg loom"`, the same names resolve to
//! [loom](https://docs.rs/loom)'s modeled primitives, so the loom models
//! in `tests/loom_sched.rs` exhaustively explore thread interleavings of
//! the **real** scheduler and channel code — not a re-implementation.
//! That is what upgrades the repo's sharded ≡ serial equivalence story
//! from "hand-reviewed" to "model-checked": the at-most-once-scheduled
//! actor invariant, per-band FIFO order, drain quiescence and
//! park/unpark wakeup correctness are all explored exhaustively under
//! `--cfg loom` (see `make loom`).
//!
//! Repo law (enforced by `cargo xtask lint-invariants`): concurrency
//! code imports these names from here, never from `std::sync` directly,
//! and never constructs an **unbounded** queue — [`chan`] is bounded by
//! construction, which is why backpressure propagates instead of
//! buffering a hot producer unboundedly.

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Thread spawn/join, loom-switched like the rest of the facade.
pub mod thread {
    #[cfg(loom)]
    pub use loom::thread::{spawn, JoinHandle};
    #[cfg(not(loom))]
    pub use std::thread::{spawn, JoinHandle};
}

/// The supervision boundary: run `f`, converting a panic into
/// `Err(message)` instead of unwinding into pool/queue bookkeeping.
///
/// This is the loom-compatible face of `std::panic::catch_unwind` —
/// loom does not model unwinding, so under `--cfg loom` the closure
/// runs bare and the boundary is a transparent `Ok`. That keeps the
/// loom models driving the *real* worker-loop code (claim, run,
/// put-back, requeue) while the panic-isolation property itself is
/// exercised by the non-loom scheduler and chaos tests.
#[cfg(not(loom))]
pub fn catch_boundary<T>(f: impl FnOnce() -> T) -> Result<T, String> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(v) => Ok(v),
        Err(payload) => Err(panic_message(&payload)),
    }
}

/// See the non-loom `catch_boundary`: under loom the closure runs bare
/// (loom cannot model unwinding), so the boundary is transparent.
#[cfg(loom)]
pub fn catch_boundary<T>(f: impl FnOnce() -> T) -> Result<T, String> {
    Ok(f())
}

/// Best-effort human-readable message out of a panic payload.
#[cfg(not(loom))]
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

pub mod chan {
    //! A bounded MPSC channel on the loom-switchable facade.
    //!
    //! Semantically a subset of `std::sync::mpsc::sync_channel`:
    //! [`Sender::send`] blocks while the queue sits at capacity
    //! (backpressure propagates to the producer) and errs once the
    //! receiver is gone; [`Receiver::recv`] blocks while empty and errs
    //! once every sender is gone; iteration ends on disconnect. The
    //! whole concurrency stack uses this instead of `std::sync::mpsc`
    //! so (a) the loom models exercise the exact channel the shards
    //! run on, and (b) the bounded-queue law is structural — there is
    //! no unbounded constructor to reach for.

    use super::{Arc, Condvar, Mutex};
    use std::collections::VecDeque;
    use std::fmt;

    /// `send` on a channel whose receiver was dropped; returns the
    /// unsent value.
    pub struct SendError<T>(pub T);

    impl<T> fmt::Debug for SendError<T> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("SendError(receiver dropped)")
        }
    }

    /// `recv` on an empty channel whose senders were all dropped.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub struct RecvError;

    struct Inner<T> {
        queue: VecDeque<T>,
        senders: usize,
        rx_alive: bool,
    }

    struct Shared<T> {
        cap: usize,
        inner: Mutex<Inner<T>>,
        /// Signaled on push and on last-sender drop (wakes `recv`).
        not_empty: Condvar,
        /// Signaled on pop and on receiver drop (wakes blocked `send`).
        not_full: Condvar,
    }

    /// The producing half (cloneable — MPSC).
    pub struct Sender<T> {
        shared: Arc<Shared<T>>,
    }

    /// The consuming half (single consumer).
    pub struct Receiver<T> {
        shared: Arc<Shared<T>>,
    }

    /// A bounded channel with room for `cap.max(1)` queued values.
    pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
        let shared = Arc::new(Shared {
            cap: cap.max(1),
            inner: Mutex::new(Inner { queue: VecDeque::new(), senders: 1, rx_alive: true }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        });
        (Sender { shared: shared.clone() }, Receiver { shared })
    }

    impl<T> Sender<T> {
        /// Queue `value`, blocking while the channel is full. Errs (and
        /// hands the value back) once the receiver is dropped — senders
        /// blocked in `send` are woken and err too, so producers never
        /// wedge on an abandoned channel.
        pub fn send(&self, value: T) -> Result<(), SendError<T>> {
            let mut inner = self.shared.inner.lock().expect("chan lock");
            loop {
                if !inner.rx_alive {
                    return Err(SendError(value));
                }
                if inner.queue.len() < self.shared.cap {
                    break;
                }
                inner = self.shared.not_full.wait(inner).expect("chan lock");
            }
            inner.queue.push_back(value);
            drop(inner);
            self.shared.not_empty.notify_one();
            Ok(())
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Self {
            let mut inner = self.shared.inner.lock().expect("chan lock");
            inner.senders += 1;
            drop(inner);
            Sender { shared: self.shared.clone() }
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            let mut inner = self.shared.inner.lock().expect("chan lock");
            inner.senders -= 1;
            let disconnected = inner.senders == 0;
            drop(inner);
            if disconnected {
                // The receiver may be parked in `recv` waiting for a
                // value that will never come.
                self.shared.not_empty.notify_all();
            }
        }
    }

    impl<T> Receiver<T> {
        /// Dequeue the next value, blocking while the channel is empty.
        /// Errs once the channel is both empty and sender-less.
        pub fn recv(&self) -> Result<T, RecvError> {
            let mut inner = self.shared.inner.lock().expect("chan lock");
            loop {
                if let Some(v) = inner.queue.pop_front() {
                    drop(inner);
                    self.shared.not_full.notify_one();
                    return Ok(v);
                }
                if inner.senders == 0 {
                    return Err(RecvError);
                }
                inner = self.shared.not_empty.wait(inner).expect("chan lock");
            }
        }

        /// Blocking iterator over received values; ends on disconnect.
        pub fn iter(&self) -> Iter<'_, T> {
            Iter { rx: self }
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            let mut inner = self.shared.inner.lock().expect("chan lock");
            inner.rx_alive = false;
            drop(inner);
            // Senders may be parked in `send` waiting for room.
            self.shared.not_full.notify_all();
        }
    }

    /// See [`Receiver::iter`].
    pub struct Iter<'a, T> {
        rx: &'a Receiver<T>,
    }

    impl<T> Iterator for Iter<'_, T> {
        type Item = T;
        fn next(&mut self) -> Option<T> {
            self.rx.recv().ok()
        }
    }

    /// Owning blocking iterator (`for msg in rx`); ends on disconnect.
    pub struct IntoIter<T> {
        rx: Receiver<T>,
    }

    impl<T> Iterator for IntoIter<T> {
        type Item = T;
        fn next(&mut self) -> Option<T> {
            self.rx.recv().ok()
        }
    }

    impl<T> IntoIterator for Receiver<T> {
        type Item = T;
        type IntoIter = IntoIter<T>;
        fn into_iter(self) -> IntoIter<T> {
            IntoIter { rx: self }
        }
    }

    impl<'a, T> IntoIterator for &'a Receiver<T> {
        type Item = T;
        type IntoIter = Iter<'a, T>;
        fn into_iter(self) -> Iter<'a, T> {
            self.iter()
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::chan;

    #[test]
    fn fifo_within_one_sender() {
        let (tx, rx) = chan::bounded(8);
        for k in 0..5 {
            tx.send(k).expect("send");
        }
        drop(tx);
        let got: Vec<i32> = rx.iter().collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn recv_errs_after_all_senders_drop() {
        let (tx, rx) = chan::bounded::<u8>(2);
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(7).expect("send");
        drop(tx2);
        assert_eq!(rx.recv(), Ok(7));
        assert_eq!(rx.recv(), Err(chan::RecvError));
    }

    #[test]
    fn send_errs_after_receiver_drop() {
        let (tx, rx) = chan::bounded(2);
        drop(rx);
        assert!(tx.send(1).is_err(), "send to a dropped receiver must err");
    }

    #[test]
    fn capacity_blocks_until_consumed() {
        let (tx, rx) = chan::bounded(1);
        tx.send(1u64).expect("send");
        // The second send must block until the consumer drains one slot;
        // run it on a helper thread and unblock it from here.
        let h = std::thread::spawn(move || tx.send(2).expect("send"));
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
        h.join().expect("join");
        assert_eq!(rx.recv(), Err(chan::RecvError));
    }

    #[test]
    fn blocked_sender_wakes_on_receiver_drop() {
        let (tx, rx) = chan::bounded(1);
        tx.send(1u8).expect("send");
        let h = std::thread::spawn(move || tx.send(2).is_err());
        // Dropping the receiver must wake the parked sender with an error
        // instead of wedging it forever.
        drop(rx);
        assert!(h.join().expect("join"), "parked sender must err after rx drop");
    }

    #[test]
    fn many_producers_conserve_messages() {
        let (tx, rx) = chan::bounded(4);
        let handles: Vec<_> = (0..4u64)
            .map(|p| {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    for k in 0..25u64 {
                        tx.send(p * 100 + k).expect("send");
                    }
                })
            })
            .collect();
        drop(tx);
        let mut got: Vec<u64> = rx.iter().collect();
        for h in handles {
            h.join().expect("join");
        }
        assert_eq!(got.len(), 100);
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 100, "no message lost or duplicated");
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let (tx, rx) = chan::bounded(0);
        tx.send(42).expect("send");
        assert_eq!(rx.recv(), Ok(42));
    }
}
