//! Shared utilities: deterministic RNG, statistics, curve fitting, the
//! in-repo property-testing harness (offline substitutes for `rand`,
//! `statrs`, and `proptest`), and the readout kernels shared by every
//! decaying representation: the quantized decay LUT ([`decay`]), the
//! per-row active-pixel tracker ([`active`]), the epoch-bucketed recency
//! bitmask planes backing the STCF support fast path ([`bitplane`]), the
//! set-associative sparse recency store behind the O(m) cache STCF
//! backend ([`sparse`]), the scoped-thread row parallelism helpers
//! ([`parallel`]), the loom-switchable concurrency facade ([`sync`]),
//! the generic per-actor-FIFO worker pool behind the serve scheduler
//! ([`actor`]) and the lock-light metrics registry behind the fleet's
//! observability plane ([`telemetry`]).

pub mod active;
pub mod actor;
pub mod bench;
pub mod bitplane;
pub mod check;
pub mod decay;
pub mod fit;
pub mod grid;
pub mod image;
pub mod parallel;
pub mod rng;
pub mod sparse;
pub mod stats;
pub mod sync;
pub mod telemetry;
