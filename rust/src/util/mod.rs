//! Shared utilities: deterministic RNG, statistics, curve fitting, and the
//! in-repo property-testing harness (offline substitutes for `rand`,
//! `statrs`, and `proptest`).

pub mod bench;
pub mod check;
pub mod fit;
pub mod grid;
pub mod image;
pub mod rng;
pub mod stats;
