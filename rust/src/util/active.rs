//! Per-row active-pixel tracking for activity-proportional frame readout.
//!
//! The paper's energy argument (Sec. IV) is that passive decay costs
//! nothing on idle pixels; the software twin exploits the same sparsity.
//! An [`ActiveSet`] records which pixels of a plane currently hold a
//! live (non-expired) write, kept as one `Vec<u16>` of x-coordinates per
//! sensor row plus a per-pixel membership flag for O(1) dedup. A frame
//! readout then zero-fills its output buffer once (a vectorized memset)
//! and touches only listed pixels — O(active) instead of O(H·W).
//!
//! Expiry is pruned *on the write path* (the only `&mut` path), amortized
//! by a write budget: a full O(len) prune scan runs only once at least
//! `max(len, 256)` writes have accrued since the last scan, so the
//! per-write cost stays O(1) amortized at every activity level (a scan
//! is always paid for by at least as many writes as entries it walks,
//! and a fully-active plane cannot trigger back-to-back scans). Between
//! scans the set may hold entries that have already decayed past the
//! memory horizon; readout is still exact because an expired pixel's
//! value is *defined* as 0 (see
//! [`crate::util::decay::DecayLut::horizon_us`]) and the zero-fill
//! already wrote it. Stale entries are gone within one budget window of
//! the activity dropping.
//!
//! Contract: pruning uses the stream clock (the latest ingested event
//! time) as "now", so active-set readout is bit-for-bit identical to a
//! dense scan for every query time `t_us` ≥ that clock — the causal
//! serving case. Querying a frame *behind* the stream head may miss
//! pixels that have already expired relative to the head.

/// A prune scan needs at least this many accrued writes — small sets
/// are cheap to walk anyway, and this keeps tiny sensors scan-free.
pub const MIN_PRUNE_BUDGET: usize = 256;

/// Per-row lists of currently-active pixel x-coordinates.
#[derive(Clone, Debug)]
pub struct ActiveSet {
    width: usize,
    /// Active x's per sensor row (unordered within a row).
    rows: Vec<Vec<u16>>,
    /// Per-pixel membership flag (dedup for [`ActiveSet::mark`]).
    listed: Vec<bool>,
    /// Total listed pixels across all rows.
    len: usize,
    /// Writes accrued since the last prune scan.
    budget: usize,
}

impl ActiveSet {
    pub fn new(width: usize, height: usize) -> Self {
        assert!(width > 0 && height > 0, "empty active set");
        Self {
            width,
            rows: vec![Vec::new(); height],
            listed: vec![false; width * height],
            len: 0,
            budget: 0,
        }
    }

    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }

    #[inline]
    pub fn height(&self) -> usize {
        self.rows.len()
    }

    /// Total listed pixels.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Active x-coordinates of row `y` (unordered).
    #[inline]
    pub fn row(&self, y: usize) -> &[u16] {
        &self.rows[y]
    }

    /// Record a write at (x, y); idempotent while the pixel stays listed.
    #[inline]
    pub fn mark(&mut self, x: u16, y: u16) {
        let i = y as usize * self.width + x as usize;
        if !self.listed[i] {
            self.listed[i] = true;
            self.rows[y as usize].push(x);
            self.len += 1;
        }
    }

    /// Amortized prune: accrue `writes` to the scan budget and run a full
    /// [`ActiveSet::prune`] scan once the budget reaches
    /// `max(len, MIN_PRUNE_BUDGET)` — the scan is then paid for by at
    /// least as many writes as entries it walks, O(1) amortized per
    /// write regardless of how much the scan retains. Call on the write
    /// path with an `expired(x, y)` predicate derived from the stream
    /// clock and the memory horizon.
    #[inline]
    pub fn maybe_prune(&mut self, writes: usize, expired: impl FnMut(u16, usize) -> bool) {
        self.budget += writes;
        if self.budget >= self.len.max(MIN_PRUNE_BUDGET) {
            self.prune(expired);
            self.budget = 0;
        }
    }

    /// Amortized age-based expiry against a row-major stamp plane
    /// (`stamps[y·width + x]` = last write µs, 0 = never): accrue
    /// `writes` and, once the budget covers a scan, drop pixels older
    /// than `horizon_us` at `clock_us`. The one expiry rule shared by
    /// every pruning caller — change it here, not at call sites.
    #[inline]
    pub fn maybe_prune_expired(
        &mut self,
        writes: usize,
        stamps: &[u64],
        clock_us: u64,
        horizon_us: u64,
    ) {
        let w = self.width;
        self.maybe_prune(writes, |x, y| {
            clock_us.saturating_sub(stamps[y * w + x as usize]) > horizon_us
        });
    }

    /// Immediate (non-amortized) variant of
    /// [`ActiveSet::maybe_prune_expired`].
    pub fn prune_expired(&mut self, stamps: &[u64], clock_us: u64, horizon_us: u64) {
        let w = self.width;
        self.prune(|x, y| clock_us.saturating_sub(stamps[y * w + x as usize]) > horizon_us);
    }

    /// Drop every listed pixel for which `expired(x, y)` holds. O(len).
    pub fn prune(&mut self, mut expired: impl FnMut(u16, usize) -> bool) {
        let w = self.width;
        let listed = &mut self.listed;
        let mut len = 0usize;
        for (y, row) in self.rows.iter_mut().enumerate() {
            row.retain(|&x| {
                let keep = !expired(x, y);
                if !keep {
                    listed[y * w + x as usize] = false;
                }
                keep
            });
            len += row.len();
        }
        self.len = len;
    }

    /// Forget every pixel (power-on reset).
    pub fn clear(&mut self) {
        for row in &mut self.rows {
            row.clear();
        }
        self.listed.fill(false);
        self.len = 0;
        self.budget = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mark_dedups_and_counts() {
        let mut a = ActiveSet::new(8, 4);
        a.mark(3, 1);
        a.mark(3, 1);
        a.mark(4, 1);
        assert_eq!(a.len(), 2);
        assert_eq!(a.row(1).len(), 2);
        assert!(a.row(0).is_empty());
        assert!(!a.is_empty());
    }

    #[test]
    fn prune_unlists_so_remark_works() {
        let mut a = ActiveSet::new(8, 2);
        a.mark(1, 0);
        a.mark(2, 0);
        a.prune(|x, _| x == 1);
        assert_eq!(a.len(), 1);
        assert_eq!(a.row(0), &[2]);
        // A pruned pixel can re-enter the set.
        a.mark(1, 0);
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn maybe_prune_amortizes_scans_against_write_budget() {
        let mut a = ActiveSet::new(64, 64);
        let mut probed = 0usize;
        // One distinct mark + one accrued write per step: the first full
        // scan fires exactly when the budget catches the listed count.
        for i in 0..MIN_PRUNE_BUDGET {
            a.mark((i % 64) as u16, (i / 64) as u16);
            a.maybe_prune(1, |_, _| {
                probed += 1;
                false
            });
        }
        assert_eq!(probed, MIN_PRUNE_BUDGET, "exactly one full scan");
        // Nothing expired, so the next scan needs a fresh budget of
        // max(len, MIN) writes — a few more writes must not rescan.
        a.maybe_prune(10, |_, _| {
            probed += 1;
            false
        });
        assert_eq!(probed, MIN_PRUNE_BUDGET);
        // Accruing a full budget triggers the scan; everything expires.
        a.maybe_prune(MIN_PRUNE_BUDGET, |_, _| true);
        assert!(a.is_empty());
    }

    #[test]
    fn clear_resets_everything() {
        let mut a = ActiveSet::new(4, 4);
        a.mark(0, 0);
        a.mark(3, 3);
        a.clear();
        assert!(a.is_empty());
        assert!(a.row(0).is_empty());
        a.mark(0, 0);
        assert_eq!(a.len(), 1);
    }
}
