//! Per-row active-pixel tracking for activity-proportional frame readout.
//!
//! The paper's energy argument (Sec. IV) is that passive decay costs
//! nothing on idle pixels; the software twin exploits the same sparsity.
//! An [`ActiveSet`] records which pixels of a plane currently hold a
//! live (non-expired) write, kept as one `Vec<u16>` of x-coordinates per
//! sensor row plus a per-pixel membership flag for O(1) dedup. A frame
//! readout then zero-fills its output buffer once (a vectorized memset)
//! and touches only listed pixels — O(active) instead of O(H·W).
//!
//! Expiry is pruned *on the write path* (the only `&mut` path), amortized
//! by a write budget: a full O(len) prune scan runs only once at least
//! `max(len, 256)` writes have accrued since the last scan, so the
//! per-write cost stays O(1) amortized at every activity level (a scan
//! is always paid for by at least as many writes as entries it walks,
//! and a fully-active plane cannot trigger back-to-back scans). Between
//! scans the set may hold entries that have already decayed past the
//! memory horizon; readout is still exact because an expired pixel's
//! value is *defined* as 0 (see
//! [`crate::util::decay::DecayLut::horizon_us`]) and the zero-fill
//! already wrote it. Stale entries are gone within one budget window of
//! the activity dropping.
//!
//! Contract: pruning uses the stream clock (the latest ingested event
//! time) as "now", so active-set readout is bit-for-bit identical to a
//! dense scan for every query time `t_us` ≥ that clock — the causal
//! serving case. Querying a frame *behind* the stream head may miss
//! pixels that have already expired relative to the head.

/// A prune scan needs at least this many accrued writes — small sets
/// are cheap to walk anyway, and this keeps tiny sensors scan-free.
pub const MIN_PRUNE_BUDGET: usize = 256;

/// Dense-fallback activity threshold α: once a plane lists more than
/// α·rows·width pixels, the zero-fill + list-walk readout pays its
/// constants (indexed stores, run bookkeeping) on nearly every pixel and
/// a straight dense row scan wins. Readout paths switch automatically via
/// [`ActiveSet::denser_than`]. The default is the bench-sweep crossover
/// (`bench_tsurface` sweeps α ∈ {5, 10, 20, 40 %} and prints the measured
/// crossover each run so this constant can be re-tuned); the two modes
/// are bit-for-bit interchangeable for causal queries (`t_us` ≥ the
/// stream clock — see the module contract above), so the switch never
/// changes a served frame.
pub const DENSE_FALLBACK_ALPHA: f64 = 0.20;

/// Per-row lists of currently-active pixel x-coordinates.
#[derive(Clone, Debug)]
pub struct ActiveSet {
    width: usize,
    /// Active x's per sensor row (unordered within a row).
    rows: Vec<Vec<u16>>,
    /// Per-pixel membership flag (dedup for [`ActiveSet::mark`]).
    listed: Vec<bool>,
    /// Total listed pixels across all rows.
    len: usize,
    /// Writes accrued since the last prune scan.
    budget: usize,
}

impl ActiveSet {
    pub fn new(width: usize, height: usize) -> Self {
        assert!(width > 0 && height > 0, "empty active set");
        Self {
            width,
            rows: vec![Vec::new(); height],
            listed: vec![false; width * height],
            len: 0,
            budget: 0,
        }
    }

    #[inline]
    pub fn width(&self) -> usize {
        self.width
    }

    #[inline]
    pub fn height(&self) -> usize {
        self.rows.len()
    }

    /// Total listed pixels.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Active x-coordinates of row `y` (unordered).
    #[inline]
    pub fn row(&self, y: usize) -> &[u16] {
        &self.rows[y]
    }

    /// Does the listed fraction exceed `alpha` of the plane? Readout
    /// paths use this with [`DENSE_FALLBACK_ALPHA`] to fall back to a
    /// dense row scan at high activity.
    #[inline]
    pub fn denser_than(&self, alpha: f64) -> bool {
        self.len as f64 > alpha * (self.width * self.rows.len()) as f64
    }

    /// Contiguous row ranges for a chunked render over this set's plane:
    /// one whole-plane range when `chunks <= 1`, else weight-balanced by
    /// per-row active counts (or the row width once the dense fallback
    /// is active) via [`crate::util::parallel::balanced_row_ranges`].
    pub fn render_ranges(&self, dense: bool, chunks: usize) -> Vec<std::ops::Range<usize>> {
        let h = self.rows.len();
        let chunks = chunks.clamp(1, h);
        if chunks == 1 {
            return vec![0..h];
        }
        let weights: Vec<usize> =
            (0..h).map(|y| 1 + if dense { self.width } else { self.rows[y].len() }).collect();
        crate::util::parallel::balanced_row_ranges(&weights, chunks)
    }

    /// Record a write at (x, y); idempotent while the pixel stays listed.
    #[inline]
    pub fn mark(&mut self, x: u16, y: u16) {
        let i = y as usize * self.width + x as usize;
        if !self.listed[i] {
            self.listed[i] = true;
            self.rows[y as usize].push(x);
            self.len += 1;
        }
    }

    /// Amortized prune: accrue `writes` to the scan budget and run a full
    /// [`ActiveSet::prune`] scan once the budget reaches
    /// `max(len, MIN_PRUNE_BUDGET)` — the scan is then paid for by at
    /// least as many writes as entries it walks, O(1) amortized per
    /// write regardless of how much the scan retains. Call on the write
    /// path with an `expired(x, y)` predicate derived from the stream
    /// clock and the memory horizon.
    #[inline]
    pub fn maybe_prune(&mut self, writes: usize, expired: impl FnMut(u16, usize) -> bool) {
        self.budget += writes;
        if self.budget >= self.len.max(MIN_PRUNE_BUDGET) {
            self.prune(expired);
            self.budget = 0;
        }
    }

    /// Amortized age-based expiry against a row-major stamp plane
    /// (`stamps[y·width + x]` = last write µs, 0 = never): accrue
    /// `writes` and, once the budget covers a scan, drop pixels older
    /// than `horizon_us` at `clock_us`. The one expiry rule shared by
    /// every pruning caller — change it here, not at call sites.
    #[inline]
    pub fn maybe_prune_expired(
        &mut self,
        writes: usize,
        stamps: &[u64],
        clock_us: u64,
        horizon_us: u64,
    ) {
        let w = self.width;
        self.maybe_prune(writes, |x, y| {
            clock_us.saturating_sub(stamps[y * w + x as usize]) > horizon_us
        });
    }

    /// Immediate (non-amortized) variant of
    /// [`ActiveSet::maybe_prune_expired`].
    pub fn prune_expired(&mut self, stamps: &[u64], clock_us: u64, horizon_us: u64) {
        let w = self.width;
        self.prune(|x, y| clock_us.saturating_sub(stamps[y * w + x as usize]) > horizon_us);
    }

    /// Drop every listed pixel for which `expired(x, y)` holds. O(len).
    pub fn prune(&mut self, mut expired: impl FnMut(u16, usize) -> bool) {
        let w = self.width;
        let listed = &mut self.listed;
        let mut len = 0usize;
        for (y, row) in self.rows.iter_mut().enumerate() {
            row.retain(|&x| {
                let keep = !expired(x, y);
                if !keep {
                    listed[y * w + x as usize] = false;
                }
                keep
            });
            len += row.len();
        }
        self.len = len;
    }

    /// Resident bytes (struct, per-row list capacities, membership
    /// flags). The `listed` flag plane is O(H·W) by construction — the
    /// dense term the sparse session-memory work accounts for honestly
    /// (see [`crate::util::sparse`] for the O(m) alternative).
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.rows.iter().map(|r| r.capacity() * std::mem::size_of::<u16>()).sum::<usize>()
            + self.rows.capacity() * std::mem::size_of::<Vec<u16>>()
            + self.listed.capacity()
    }

    /// Forget every pixel (power-on reset).
    pub fn clear(&mut self) {
        for row in &mut self.rows {
            row.clear();
        }
        self.listed.fill(false);
        self.len = 0;
        self.budget = 0;
    }
}

/// Walk the sorted contiguous column runs of one row's active list:
/// `f(x0..x1)` is invoked once per maximal run of consecutive x's.
/// Entries are unique (the `mark` dedup), so a run maps 1:1 onto a
/// contiguous cell slice — the unit of the batched LUT gathers in the
/// readout inner loops. `scratch` holds the sort copy (rows are stored
/// unordered) and is reused across calls.
#[inline]
pub fn for_each_sorted_run(
    xs: &[u16],
    scratch: &mut Vec<u16>,
    mut f: impl FnMut(std::ops::Range<usize>),
) {
    scratch.clear();
    scratch.extend_from_slice(xs);
    scratch.sort_unstable();
    let mut i = 0usize;
    while i < scratch.len() {
        let x0 = scratch[i] as usize;
        let mut j = i + 1;
        while j < scratch.len() && scratch[j] as usize == x0 + (j - i) {
            j += 1;
        }
        f(x0..x0 + (j - i));
        i = j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mark_dedups_and_counts() {
        let mut a = ActiveSet::new(8, 4);
        a.mark(3, 1);
        a.mark(3, 1);
        a.mark(4, 1);
        assert_eq!(a.len(), 2);
        assert_eq!(a.row(1).len(), 2);
        assert!(a.row(0).is_empty());
        assert!(!a.is_empty());
    }

    #[test]
    fn prune_unlists_so_remark_works() {
        let mut a = ActiveSet::new(8, 2);
        a.mark(1, 0);
        a.mark(2, 0);
        a.prune(|x, _| x == 1);
        assert_eq!(a.len(), 1);
        assert_eq!(a.row(0), &[2]);
        // A pruned pixel can re-enter the set.
        a.mark(1, 0);
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn maybe_prune_amortizes_scans_against_write_budget() {
        let mut a = ActiveSet::new(64, 64);
        let mut probed = 0usize;
        // One distinct mark + one accrued write per step: the first full
        // scan fires exactly when the budget catches the listed count.
        for i in 0..MIN_PRUNE_BUDGET {
            a.mark((i % 64) as u16, (i / 64) as u16);
            a.maybe_prune(1, |_, _| {
                probed += 1;
                false
            });
        }
        assert_eq!(probed, MIN_PRUNE_BUDGET, "exactly one full scan");
        // Nothing expired, so the next scan needs a fresh budget of
        // max(len, MIN) writes — a few more writes must not rescan.
        a.maybe_prune(10, |_, _| {
            probed += 1;
            false
        });
        assert_eq!(probed, MIN_PRUNE_BUDGET);
        // Accruing a full budget triggers the scan; everything expires.
        a.maybe_prune(MIN_PRUNE_BUDGET, |_, _| true);
        assert!(a.is_empty());
    }

    #[test]
    fn sorted_runs_partition_the_row() {
        let mut scratch = Vec::new();
        let mut runs: Vec<(usize, usize)> = Vec::new();
        for_each_sorted_run(&[7, 2, 3, 9, 4, 12], &mut scratch, |r| runs.push((r.start, r.end)));
        assert_eq!(runs, vec![(2, 5), (7, 8), (9, 10), (12, 13)]);
        runs.clear();
        for_each_sorted_run(&[], &mut scratch, |r| runs.push((r.start, r.end)));
        assert!(runs.is_empty());
    }

    #[test]
    fn render_ranges_cover_and_respect_chunks() {
        let mut a = ActiveSet::new(8, 10);
        for x in 0..8u16 {
            a.mark(x, 9); // all the activity in the last row
        }
        let one = a.render_ranges(false, 1);
        assert_eq!(one, vec![0..10]);
        let four = a.render_ranges(false, 4);
        assert_eq!(four.first().unwrap().start, 0);
        assert_eq!(four.last().unwrap().end, 10);
        assert!(four.len() <= 4 && !four.is_empty());
        // More chunks than rows still covers every row exactly once.
        let many = a.render_ranges(true, 64);
        assert_eq!(many.len(), 10);
    }

    #[test]
    fn denser_than_tracks_listed_fraction() {
        let mut a = ActiveSet::new(10, 10);
        for k in 0..21u16 {
            a.mark(k % 10, k / 10);
        }
        assert!(a.denser_than(0.20), "21/100 listed > 20 %");
        assert!(!a.denser_than(0.21));
        assert!(!a.denser_than(DENSE_FALLBACK_ALPHA) || DENSE_FALLBACK_ALPHA < 0.21);
    }

    #[test]
    fn clear_resets_everything() {
        let mut a = ActiveSet::new(4, 4);
        a.mark(0, 0);
        a.mark(3, 3);
        a.clear();
        assert!(a.is_empty());
        assert!(a.row(0).is_empty());
        a.mark(0, 0);
        assert_eq!(a.len(), 1);
    }
}
