//! Per-row recency bitmask planes for the STCF support fast path.
//!
//! The paper's point about support checking (Sec. IV-C, Fig. 10b) is
//! that "was this neighbour recently active?" collapses to a binary
//! comparator test per cell. A [`RecencyPlane`] is the software image of
//! that observation: one bit per pixel, packed into `u64` words per
//! sensor row, where a set bit means the pixel *possibly* holds a write
//! recent enough to matter and a clear bit means it *provably* does not.
//! A patch-support query then masks the few words covering the patch
//! window, popcounts them, skips all-zero rows outright, and confirms
//! only the set-bit runs against the exact timestamp / comparator test —
//! O(patch words) instead of O(patch pixels) on the (common) sparse
//! rows, and bit-for-bit equal to the exact scan because the bitmask is
//! a conservative superset.
//!
//! ## Epoch-bucketed lazy ageing
//!
//! Bits must *expire*: a pixel written long ago is no longer recent, but
//! clearing its bit eagerly would need a scan on every write. Instead,
//! time is divided into epochs of `epoch_us` and the plane keeps
//! [`EPOCH_BUCKETS`] bitmask buckets, bucket `b` holding the writes of
//! the epochs `e ≡ b (mod EPOCH_BUCKETS)`. A write first recycles its
//! bucket if the bucket still holds an older epoch (one `memset` per
//! bucket per epoch — amortized to nothing) and then sets its bit. A
//! query at time `t` ORs only the buckets whose epoch is within
//! `EPOCH_BUCKETS − 1` of `t`'s epoch, so a clear bit guarantees
//!
//! > age > (EPOCH_BUCKETS − 1) · epoch_us ≥ window_us,
//!
//! i.e. the pixel cannot pass any recency test with a window up to
//! [`RecencyPlane::window_us`] ([`RecencyPlane::covers`] is the gate).
//! Set bits can be up to `EPOCH_BUCKETS · epoch_us ≈ 1.33 · window`
//! stale — false positives the exact confirmation filters out. A bucket
//! is only ever recycled by a mark in a *newer* epoch, so marks arriving
//! out of time order cannot wipe recent bits (a late mark lands in the
//! newer-tagged bucket instead — more conservative, never less).
//!
//! ## Causality contract
//!
//! Like the active-set readout ([`crate::util::active`]), the
//! no-false-negative guarantee holds for queries at or ahead of the
//! stream head (`t_us` ≥ every marked time). A bucket is only recycled
//! by a write at least `EPOCH_BUCKETS − 1` epochs after the writes it
//! held, so by the time a recent bit could be lost, the query time that
//! made it recent has necessarily passed. Querying *behind* the stream
//! head may miss bits recycled by later writes; callers that need
//! non-causal queries must use the exact scan.

use std::ops::Range;

/// Number of epoch buckets. Four buckets bound the staleness of a set
/// bit at `4/3 ·` window (versus `2 ·` window for the minimal two) while
/// keeping the per-write bucket lookup a mask.
pub const EPOCH_BUCKETS: usize = 4;

/// One-bit-per-pixel recency plane with epoch-bucketed lazy ageing.
#[derive(Clone, Debug)]
pub struct RecencyPlane {
    width: usize,
    words_per_row: usize,
    epoch_us: u64,
    /// `EPOCH_BUCKETS` bit planes of `height · words_per_row` words.
    buckets: Vec<Vec<u64>>,
    /// Epoch currently held by each bucket (`u64::MAX` = empty).
    bucket_epoch: [u64; EPOCH_BUCKETS],
}

impl RecencyPlane {
    /// Plane guaranteeing no false negatives for recency windows up to
    /// `window_us` (see [`RecencyPlane::covers`]).
    pub fn new(width: usize, height: usize, window_us: u64) -> Self {
        assert!(width > 0 && height > 0, "empty recency plane");
        let epoch_us = window_us.div_ceil(EPOCH_BUCKETS as u64 - 1).max(1);
        let words_per_row = width.div_ceil(64);
        Self {
            width,
            words_per_row,
            epoch_us,
            buckets: (0..EPOCH_BUCKETS).map(|_| vec![0u64; height * words_per_row]).collect(),
            bucket_epoch: [u64::MAX; EPOCH_BUCKETS],
        }
    }

    /// Largest recency window (µs) this plane guarantees: a clear bit
    /// implies the pixel's last write is older than this at any causal
    /// query time.
    #[inline]
    pub fn window_us(&self) -> u64 {
        (EPOCH_BUCKETS as u64 - 1) * self.epoch_us
    }

    /// Does the no-false-negative guarantee hold for `tau_us`? (Any
    /// window up to the construction window is covered; a clear bit
    /// means age > [`RecencyPlane::window_us`] ≥ `tau_us`.)
    #[inline]
    pub fn covers(&self, tau_us: u64) -> bool {
        tau_us <= self.window_us()
    }

    /// Bytes of bitmask storage (diagnostics).
    pub fn memory_bytes(&self) -> usize {
        self.buckets.iter().map(|b| b.len() * 8).sum()
    }

    /// Resident bytes (struct + bitmask buckets) — the serve layer's
    /// `resident_bytes` accounting convention shared by every plane type.
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.buckets.capacity() * std::mem::size_of::<Vec<u64>>()
            + self.memory_bytes()
    }

    /// Record a write at `(x, y)` at time `t_us`, recycling the target
    /// epoch bucket first if it still holds an **older** epoch. A bucket
    /// tagged with a *newer* epoch (possible only when marks arrive out
    /// of time order) is never wiped — the late mark just ORs its bit
    /// into the newer bucket, which is conservative (the bit outlives
    /// its true window; the exact confirmation filters it) where wiping
    /// would lose genuinely recent bits.
    #[inline]
    pub fn mark(&mut self, x: u16, y: u16, t_us: u64) {
        let epoch = t_us / self.epoch_us;
        let b = (epoch % EPOCH_BUCKETS as u64) as usize;
        let tag = self.bucket_epoch[b];
        if tag == u64::MAX || tag < epoch {
            self.buckets[b].fill(0);
            self.bucket_epoch[b] = epoch;
        }
        self.buckets[b][y as usize * self.words_per_row + x as usize / 64] |= 1u64 << (x % 64);
    }

    /// Popcount of possibly-recent pixels in columns `x0..=x1` of row
    /// `y` at query time `t_us` — an upper bound on the exact recent
    /// count (diagnostics and tests; the scan path uses the run walk).
    pub fn popcount_window(&self, y: usize, x0: u16, x1: u16, t_us: u64) -> u32 {
        let mut n = 0u32;
        self.for_each_possibly_recent_run(y, x0, x1, t_us, |run| n += run.len() as u32);
        n
    }

    /// Invoke `f` once per maximal run of consecutive possibly-recent
    /// columns within `x0..=x1` of row `y` (runs never span a word
    /// boundary — a longer run simply arrives as two calls). An all-zero
    /// window costs at most one word load per live epoch bucket per
    /// window word (≤ `EPOCH_BUCKETS` × 1–2) and no calls; callers
    /// confirm each run against the exact timestamp/comparator test.
    #[inline]
    pub fn for_each_possibly_recent_run(
        &self,
        y: usize,
        x0: u16,
        x1: u16,
        t_us: u64,
        mut f: impl FnMut(Range<usize>),
    ) {
        debug_assert!(x0 <= x1 && (x1 as usize) < self.width);
        let min_epoch = (t_us / self.epoch_us).saturating_sub(EPOCH_BUCKETS as u64 - 1);
        // Bucket liveness is query-global: resolve it once, not per word.
        // Buckets older than min_epoch hold only writes whose age already
        // exceeds the guaranteed window — skip them. Future tags (possible
        // only on non-causal queries) stay included: conservative, and the
        // exact confirmation filters them.
        let mut live = [0usize; EPOCH_BUCKETS];
        let mut n_live = 0usize;
        for (b, &tag) in self.bucket_epoch.iter().enumerate() {
            if tag != u64::MAX && tag >= min_epoch {
                live[n_live] = b;
                n_live += 1;
            }
        }
        if n_live == 0 {
            return;
        }
        let (w0, w1) = (x0 as usize / 64, x1 as usize / 64);
        for wi in w0..=w1 {
            let i = y * self.words_per_row + wi;
            let mut m = 0u64;
            for &b in &live[..n_live] {
                m |= self.buckets[b][i];
            }
            if wi == w0 {
                m &= !0u64 << (x0 % 64);
            }
            if wi == w1 {
                let hi = x1 % 64;
                if hi < 63 {
                    m &= (1u64 << (hi + 1)) - 1;
                }
            }
            while m != 0 {
                let start = m.trailing_zeros() as usize;
                let len = (!(m >> start)).trailing_zeros() as usize;
                f(wi * 64 + start..wi * 64 + start + len);
                if start + len >= 64 {
                    break;
                }
                m &= !(((1u64 << len) - 1) << start);
            }
        }
    }

    /// Forget every bit (power-on reset).
    pub fn clear(&mut self) {
        for b in &mut self.buckets {
            b.fill(0);
        }
        self.bucket_epoch = [u64::MAX; EPOCH_BUCKETS];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runs(p: &RecencyPlane, y: usize, x0: u16, x1: u16, t: u64) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        p.for_each_possibly_recent_run(y, x0, x1, t, |r| v.push((r.start, r.end)));
        v
    }

    #[test]
    fn fresh_marks_are_visible_and_masked_to_the_window() {
        let mut p = RecencyPlane::new(100, 4, 24_000);
        p.mark(3, 1, 1_000);
        p.mark(5, 1, 1_200);
        p.mark(6, 1, 1_300);
        p.mark(70, 1, 1_400); // second word
        assert_eq!(runs(&p, 1, 0, 99, 2_000), vec![(3, 4), (5, 7), (70, 71)]);
        // Window clamps: x0 excludes 3, x1 excludes 70.
        assert_eq!(runs(&p, 1, 4, 69, 2_000), vec![(5, 7)]);
        // Other rows stay empty.
        assert_eq!(runs(&p, 0, 0, 99, 2_000), vec![]);
        assert_eq!(p.popcount_window(1, 0, 99, 2_000), 4);
    }

    #[test]
    fn word_boundary_columns_mask_exactly() {
        let mut p = RecencyPlane::new(130, 2, 10_000);
        for x in [0u16, 63, 64, 127, 128, 129] {
            p.mark(x, 0, 500);
        }
        let want = vec![(0, 1), (63, 64), (64, 65), (127, 128), (128, 130)];
        assert_eq!(runs(&p, 0, 0, 129, 600), want);
        assert_eq!(runs(&p, 0, 63, 64, 600), vec![(63, 64), (64, 65)]);
        assert_eq!(runs(&p, 0, 129, 129, 600), vec![(129, 130)]);
        assert_eq!(p.popcount_window(0, 0, 129, 600), 6);
    }

    #[test]
    fn full_word_run_is_one_call() {
        let mut p = RecencyPlane::new(64, 1, 1_000);
        for x in 0..64u16 {
            p.mark(x, 0, 100);
        }
        assert_eq!(runs(&p, 0, 0, 63, 200), vec![(0, 64)]);
    }

    #[test]
    fn bits_age_out_after_the_guaranteed_window() {
        let mut p = RecencyPlane::new(32, 2, 9_000); // epoch = 3 000 µs
        assert_eq!(p.window_us(), 9_000);
        p.mark(4, 0, 1_000); // epoch 0
        // Still possibly recent just inside the window...
        assert_eq!(p.popcount_window(0, 0, 31, 9_500), 1);
        // ...and excluded once the query epoch moves past the ageing
        // window, even though no write recycled the bucket.
        assert_eq!(p.popcount_window(0, 0, 31, 13_000), 0);
    }

    #[test]
    fn bucket_recycling_drops_only_expired_bits() {
        let mut p = RecencyPlane::new(32, 1, 9_000); // epoch = 3 000 µs
        p.mark(1, 0, 1_000); // epoch 0 → bucket 0
        p.mark(2, 0, 4_000); // epoch 1 → bucket 1
        // Epoch 4 maps back onto bucket 0 and must recycle it: pixel 1's
        // bit disappears, but its age (≥ 11 000) already exceeds the
        // 9 000 window, so no false negative is possible.
        p.mark(3, 0, 12_500);
        let got = runs(&p, 0, 0, 31, 12_600);
        assert_eq!(got, vec![(2, 3), (3, 4)]);
    }

    #[test]
    fn out_of_order_mark_never_wipes_a_newer_bucket() {
        let mut p = RecencyPlane::new(32, 1, 9_000); // epoch = 3 000 µs
        p.mark(4, 0, 16_000); // epoch 5 → bucket 1
        // A late mark from epoch 1 maps to the same bucket; it must not
        // recycle it (that would lose pixel 4, written 100 µs before the
        // causal query below) — its own bit just rides the newer bucket.
        p.mark(7, 0, 4_000);
        assert_eq!(runs(&p, 0, 0, 31, 16_100), vec![(4, 5), (7, 8)]);
    }

    #[test]
    fn superset_property_on_random_streams() {
        use crate::util::check::check;
        check("recency bitmask superset", 25, |g| {
            let (w, h) = (48usize, 12usize);
            let window = g.u64(1_000, 40_000);
            let mut p = RecencyPlane::new(w, h, window);
            let mut last = vec![0u64; w * h]; // 0 = never written
            let mut t = 0u64;
            for _ in 0..300 {
                t += g.u64(1, window / 4 + 1);
                let (x, y) = (g.u64(0, w as u64 - 1) as u16, g.u64(0, h as u64 - 1) as u16);
                p.mark(x, y, t);
                last[y as usize * w + x as usize] = t;
                // Causal query: every truly-recent pixel must have its
                // bit set for any tau the plane covers.
                let tau = g.u64(0, window);
                let y_q = g.u64(0, h as u64 - 1) as usize;
                let (x0, x1) = {
                    let a = g.u64(0, w as u64 - 1) as u16;
                    let b = g.u64(0, w as u64 - 1) as u16;
                    (a.min(b), a.max(b))
                };
                let mut bits = vec![false; w];
                p.for_each_possibly_recent_run(y_q, x0, x1, t, |r| {
                    for x in r {
                        bits[x] = true;
                    }
                });
                for x in x0..=x1 {
                    let tw = last[y_q * w + x as usize];
                    if tw != 0 && t - tw <= tau {
                        assert!(
                            bits[x as usize],
                            "false negative at ({x},{y_q}) t={t} tw={tw} tau={tau} win={window}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn covers_matches_guaranteed_window() {
        let p = RecencyPlane::new(16, 16, 24_000);
        assert!(p.covers(24_000));
        assert!(p.covers(1));
        assert!(p.covers(p.window_us()));
        assert!(!p.covers(p.window_us() + 1));
        assert!(p.memory_bytes() > 0);
    }

    #[test]
    fn clear_forgets_everything() {
        let mut p = RecencyPlane::new(16, 4, 5_000);
        p.mark(3, 2, 700);
        p.clear();
        assert_eq!(p.popcount_window(2, 0, 15, 800), 0);
        p.mark(3, 2, 900);
        assert_eq!(p.popcount_window(2, 0, 15, 950), 1);
    }
}
