//! Minimal benchmarking harness (offline substitute for `criterion`).
//!
//! `cargo bench` runs the `rust/benches/*.rs` binaries (harness = false);
//! each uses this helper: warmup, fixed-duration measurement, mean/σ/min
//! reporting, and a throughput variant for events/s-style numbers.

use super::stats::Running;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    /// Items processed per iteration (for throughput reporting).
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns == 0.0 {
            return 0.0;
        }
        self.items_per_iter * 1e9 / self.mean_ns
    }

    pub fn report(&self) -> String {
        let tp = if self.items_per_iter > 1.0 {
            format!("  [{:.3} Mitems/s]", self.throughput_per_sec() / 1e6)
        } else {
            String::new()
        };
        format!(
            "{:<44} {:>12.3} µs/iter ±{:>8.3} (min {:>10.3}, n={}){tp}",
            self.name,
            self.mean_ns / 1e3,
            self.stddev_ns / 1e3,
            self.min_ns / 1e3,
            self.iters
        )
    }
}

/// Benchmark `f` for ~`measure_ms` after ~`warmup_ms` of warmup.
/// `items` is the number of logical items one call of `f` processes.
pub fn bench(
    name: &str,
    items: f64,
    warmup_ms: u64,
    measure_ms: u64,
    mut f: impl FnMut(),
) -> BenchResult {
    // Warmup.
    let warm_until = Instant::now() + Duration::from_millis(warmup_ms);
    while Instant::now() < warm_until {
        f();
    }
    // Measure.
    let mut stats = Running::new();
    let measure_until = Instant::now() + Duration::from_millis(measure_ms);
    let mut iters = 0u64;
    while Instant::now() < measure_until {
        let t0 = Instant::now();
        f();
        stats.push(t0.elapsed().as_nanos() as f64);
        iters += 1;
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats.mean(),
        stddev_ns: stats.stddev(),
        min_ns: stats.min(),
        items_per_iter: items,
    }
}

/// Print a standard bench header.
pub fn header(title: &str) {
    println!("\n## {title}");
}

/// One line of a `BENCH_*.json` dump: a measurement plus any extra
/// named metrics (`pixels_per_sec`, `frames_per_sec`, …).
pub struct JsonEntry {
    pub result: BenchResult,
    pub extra: Vec<(&'static str, f64)>,
}

impl JsonEntry {
    pub fn plain(result: BenchResult) -> Self {
        Self { result, extra: Vec::new() }
    }

    pub fn with(result: BenchResult, key: &'static str, value: f64) -> Self {
        Self { result, extra: vec![(key, value)] }
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Write the standard bench-snapshot JSON (`{"benchmarks": [...]}`) that
/// CI uploads as an artifact; every entry carries `mean_ns` and `meps`
/// (items/s ÷ 1e6) plus its extra metrics.
pub fn dump_json(entries: &[JsonEntry], path: &str) {
    let mut s = String::from("{\n  \"benchmarks\": [\n");
    for (i, e) in entries.iter().enumerate() {
        let r = &e.result;
        let extra: String = e
            .extra
            .iter()
            .map(|(k, v)| format!(", \"{k}\": {v:.1}"))
            .collect();
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"mean_ns\": {:.1}, \"meps\": {:.4}{}}}{}\n",
            json_escape(&r.name),
            r.mean_ns,
            r.throughput_per_sec() / 1e6,
            extra,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write(path, s) {
        eprintln!("(could not write {path}: {e})");
    } else {
        println!("wrote {path}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_dump_shape() {
        let r = BenchResult {
            name: "a \"quoted\" name".into(),
            iters: 3,
            mean_ns: 1_000.0,
            stddev_ns: 1.0,
            min_ns: 990.0,
            items_per_iter: 10.0,
        };
        let path = std::env::temp_dir().join("tsisc_bench_dump_test.json");
        let path = path.to_str().unwrap();
        dump_json(&[JsonEntry::with(r, "frames_per_sec", 123.456)], path);
        let s = std::fs::read_to_string(path).unwrap();
        assert!(s.contains("\"benchmarks\""));
        assert!(s.contains("a \\\"quoted\\\" name"));
        assert!(s.contains("\"frames_per_sec\": 123.5"));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 100.0, 5, 25, || {
            let mut s = 0u64;
            for i in 0..1_000u64 {
                s = s.wrapping_add(i * i);
            }
            std::hint::black_box(s);
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
        assert!(r.throughput_per_sec() > 0.0);
        assert!(r.report().contains("spin"));
    }
}
