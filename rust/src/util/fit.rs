//! Nonlinear least-squares fitting of the double-exponential decay model.
//!
//! The paper (Sec. IV-C, Fig. 9) models the SPICE-simulated storage-node
//! voltage as
//!
//! ```text
//! f(t) = A1·exp(-t/τ1) + A2·exp(-t/τ2) + b
//! ```
//!
//! and maps 8 000 Monte-Carlo transients to per-pixel parameter tuples. We do
//! the same: the circuit simulator (`circuit::cell`) produces V(t) samples,
//! and this module extracts (A1, τ1, A2, τ2, b) with a small
//! Levenberg–Marquardt implementation (no external solver available offline).

use super::stats::mse;

/// Parameters of the double-exponential decay model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DoubleExp {
    pub a1: f64,
    pub tau1: f64,
    pub a2: f64,
    pub tau2: f64,
    pub b: f64,
}

impl DoubleExp {
    /// Evaluate the model at time `t` (seconds).
    #[inline]
    pub fn eval(&self, t: f64) -> f64 {
        self.a1 * (-t / self.tau1).exp() + self.a2 * (-t / self.tau2).exp() + self.b
    }

    /// Inverse: smallest t ≥ 0 with eval(t) ≤ v, found by bisection on the
    /// monotone decay (returns None if v is above the initial value or the
    /// model never decays to v within `t_max`).
    pub fn time_to_reach(&self, v: f64, t_max: f64) -> Option<f64> {
        if self.eval(0.0) <= v {
            return Some(0.0);
        }
        if self.eval(t_max) > v {
            return None;
        }
        let (mut lo, mut hi) = (0.0, t_max);
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if self.eval(mid) > v {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Some(0.5 * (lo + hi))
    }

    /// Total initial amplitude A1 + A2 + b.
    pub fn v0(&self) -> f64 {
        self.a1 + self.a2 + self.b
    }

    /// True iff the model is a guaranteed monotone decay (both amplitudes
    /// non-negative). The LM fit is unconstrained, so callers that need a
    /// physical discharge curve (e.g. the ISC array) check this and fall
    /// back to a constrained fit.
    pub fn is_monotone_decay(&self) -> bool {
        self.a1 >= 0.0 && self.a2 >= 0.0 && self.tau1 > 0.0 && self.tau2 > 0.0
    }
}

/// Result of a fit: parameters plus goodness-of-fit.
#[derive(Clone, Copy, Debug)]
pub struct FitResult {
    pub params: DoubleExp,
    pub mse: f64,
    pub iterations: usize,
}

/// Fit a double exponential to samples (t, v) with Levenberg–Marquardt.
///
/// `t` in seconds, `v` in volts. The initial guess is derived from the data:
/// the slow τ from the log-slope of the tail, the fast component from the
/// early residual. Parameters are optimized in log-space for the τs to keep
/// them positive.
pub fn fit_double_exp(t: &[f64], v: &[f64]) -> FitResult {
    assert_eq!(t.len(), v.len());
    assert!(t.len() >= 5, "need at least 5 samples");
    let n = t.len();

    // ---- initial guess ------------------------------------------------
    let v0 = v[0];
    let b0 = v[n - 1].min(0.0).max(-0.5 * v0.abs()); // decay targets ~0
    // Tail slope: use the last third of the samples.
    let third = n - n / 3;
    let mut tau_slow = estimate_tau(&t[third..], &v[third..]).unwrap_or(t[n - 1] / 2.0);
    if !(tau_slow.is_finite() && tau_slow > 0.0) {
        tau_slow = t[n - 1] / 2.0;
    }
    let tau_fast = (tau_slow / 5.0).max(t[1].max(1e-9));
    let a2 = (0.8 * v0).max(1e-6);
    let a1 = (v0 - a2).max(1e-6);
    let mut p = [a1, tau_fast.ln(), a2, tau_slow.ln(), b0];

    // ---- Levenberg–Marquardt ------------------------------------------
    let model = |p: &[f64; 5], ti: f64| -> f64 {
        p[0] * (-ti / p[1].exp()).exp() + p[2] * (-ti / p[3].exp()).exp() + p[4]
    };
    let mut lambda = 1e-3;
    let mut last_sse = sse(&p, t, v, &model);
    let mut iters = 0;
    for _ in 0..200 {
        iters += 1;
        // Jacobian (n × 5), finite differences are avoided: analytic.
        let mut jtj = [[0.0f64; 5]; 5];
        let mut jtr = [0.0f64; 5];
        for i in 0..n {
            let e1 = (-t[i] / p[1].exp()).exp();
            let e2 = (-t[i] / p[3].exp()).exp();
            let r = v[i] - (p[0] * e1 + p[2] * e2 + p[4]);
            // d/d a1, d/d ln τ1 (chain rule: ∂f/∂lnτ = f·t/τ · a e^{-t/τ}),
            // d/d a2, d/d ln τ2, d/d b
            let j = [
                e1,
                p[0] * e1 * t[i] / p[1].exp(),
                e2,
                p[2] * e2 * t[i] / p[3].exp(),
                1.0,
            ];
            for r_ in 0..5 {
                jtr[r_] += j[r_] * r;
                for c in 0..5 {
                    jtj[r_][c] += j[r_] * j[c];
                }
            }
        }
        // Damped normal equations: (JᵀJ + λ·diag) δ = Jᵀr
        let mut a = jtj;
        for d in 0..5 {
            a[d][d] += lambda * (jtj[d][d].max(1e-12));
        }
        let delta = match solve5(a, jtr) {
            Some(d) => d,
            None => break,
        };
        let mut p_new = p;
        for k in 0..5 {
            p_new[k] += delta[k];
        }
        // Clamp log-taus to sane bounds to avoid overflow.
        p_new[1] = p_new[1].clamp(-25.0, 10.0);
        p_new[3] = p_new[3].clamp(-25.0, 10.0);
        let new_sse = sse(&p_new, t, v, &model);
        if new_sse < last_sse {
            let improve = (last_sse - new_sse) / last_sse.max(1e-300);
            p = p_new;
            last_sse = new_sse;
            lambda = (lambda * 0.5).max(1e-12);
            if improve < 1e-12 {
                break;
            }
        } else {
            lambda *= 4.0;
            if lambda > 1e10 {
                break;
            }
        }
    }

    // Canonicalize: τ1 ≤ τ2 (fast first).
    let (mut a1, mut tau1) = (p[0], p[1].exp());
    let (mut a2, mut tau2) = (p[2], p[3].exp());
    if tau1 > tau2 {
        std::mem::swap(&mut a1, &mut a2);
        std::mem::swap(&mut tau1, &mut tau2);
    }
    let params = DoubleExp { a1, tau1, a2, tau2, b: p[4] };
    let fitted: Vec<f64> = t.iter().map(|&ti| params.eval(ti)).collect();
    FitResult { params, mse: mse(&fitted, v), iterations: iters }
}

fn sse(p: &[f64; 5], t: &[f64], v: &[f64], model: &dyn Fn(&[f64; 5], f64) -> f64) -> f64 {
    t.iter().zip(v).map(|(&ti, &vi)| {
        let r = vi - model(p, ti);
        r * r
    }).sum()
}

/// Estimate a single τ from ln(v) slope (v must be positive).
fn estimate_tau(t: &[f64], v: &[f64]) -> Option<f64> {
    let pts: Vec<(f64, f64)> = t
        .iter()
        .zip(v)
        .filter(|(_, &vi)| vi > 1e-9)
        .map(|(&ti, &vi)| (ti, vi.ln()))
        .collect();
    if pts.len() < 2 {
        return None;
    }
    let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let (_, slope, _) = super::stats::linreg(&xs, &ys);
    if slope >= 0.0 {
        None
    } else {
        Some(-1.0 / slope)
    }
}

/// Solve a 5×5 linear system by Gaussian elimination with partial pivoting.
fn solve5(mut a: [[f64; 5]; 5], mut b: [f64; 5]) -> Option<[f64; 5]> {
    for col in 0..5 {
        // pivot
        let mut piv = col;
        for r in col + 1..5 {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col].abs() < 1e-300 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        for r in col + 1..5 {
            let f = a[r][col] / d;
            for c in col..5 {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = [0.0f64; 5];
    for r in (0..5).rev() {
        let mut s = b[r];
        for c in r + 1..5 {
            s -= a[r][c] * x[c];
        }
        x[r] = s / a[r][r];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(params: &DoubleExp, n: usize, t_max: f64) -> (Vec<f64>, Vec<f64>) {
        let t: Vec<f64> = (0..n).map(|i| t_max * i as f64 / (n - 1) as f64).collect();
        let v: Vec<f64> = t.iter().map(|&ti| params.eval(ti)).collect();
        (t, v)
    }

    #[test]
    fn recovers_known_double_exp() {
        let truth = DoubleExp { a1: 0.153, tau1: 6.14e-3, a2: 1.047, tau2: 23.9e-3, b: 0.0 };
        let (t, v) = sample(&truth, 200, 0.06);
        let fit = fit_double_exp(&t, &v);
        assert!(fit.mse < 1e-8, "mse={}", fit.mse);
        // The reconstruction matters more than exact parameter identity
        // (double exponentials are weakly identifiable), but for clean data
        // these should land close.
        for &probe in &[0.0, 5e-3, 10e-3, 20e-3, 30e-3, 50e-3] {
            assert!(
                (fit.params.eval(probe) - truth.eval(probe)).abs() < 1e-3,
                "probe={probe} fit={} truth={}",
                fit.params.eval(probe),
                truth.eval(probe)
            );
        }
    }

    #[test]
    fn recovers_single_exp_as_degenerate() {
        let truth = DoubleExp { a1: 0.0, tau1: 1e-3, a2: 1.2, tau2: 2e-3, b: 0.0 };
        let (t, v) = sample(&truth, 120, 0.012);
        let fit = fit_double_exp(&t, &v);
        assert!(fit.mse < 1e-7, "mse={}", fit.mse);
    }

    #[test]
    fn fit_with_offset() {
        let truth = DoubleExp { a1: 0.3, tau1: 2e-3, a2: 0.8, tau2: 15e-3, b: 0.05 };
        let (t, v) = sample(&truth, 200, 0.08);
        let fit = fit_double_exp(&t, &v);
        assert!(fit.mse < 1e-7, "mse={}", fit.mse);
    }

    #[test]
    fn time_to_reach_bisects() {
        let p = DoubleExp { a1: 0.0, tau1: 1.0, a2: 1.0, tau2: 10e-3, b: 0.0 };
        // v(t)=e^{-t/10ms}; reaches 0.5 at t = 10ms·ln2
        let t = p.time_to_reach(0.5, 1.0).unwrap();
        assert!((t - 10e-3 * std::f64::consts::LN_2).abs() < 1e-7);
        assert_eq!(p.time_to_reach(2.0, 1.0), Some(0.0));
        assert_eq!(p.time_to_reach(-0.1, 1.0), None);
    }

    #[test]
    fn canonical_order_fast_first() {
        let truth = DoubleExp { a1: 0.5, tau1: 20e-3, a2: 0.7, tau2: 1e-3, b: 0.0 };
        let (t, v) = sample(&truth, 150, 0.06);
        let fit = fit_double_exp(&t, &v);
        assert!(fit.params.tau1 <= fit.params.tau2);
    }
}
