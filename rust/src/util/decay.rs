//! Shared quantized-decay kernel for every frame-readout hot path.
//!
//! All decaying representations answer the same query at readout time:
//! "a cell was written at `t_write`; what is its value at `t_us`?" The
//! answer is a pure function of the age Δt = t_us − t_write, so it can be
//! tabulated once per distinct decay curve and the readout loop becomes
//! an integer divide plus one table load — no `exp()`/`ln()` per pixel.
//!
//! [`DecayLut`] generalizes the quantized LUT that used to live privately
//! inside `isc::array`: a dense table of `rows` decay curves sampled every
//! [`DEFAULT_STEP_US`] µs (50 µs by default — the documented quantization
//! bound: for a pure exponential the readout error is at most
//! `step_us / tau_us`, since |d/dΔt e^{−Δt/τ}| ≤ 1/τ). Samples are stored
//! as `f32` (like the original `frame_lut` — half the cache footprint in
//! the gather-heavy readout loop); the ≤6·10⁻⁸ relative rounding that
//! adds is far below the binning error. It is shared by `IdealTs`,
//! `QuantizedSae`, `Tore` and `IscArray`; exact point reads
//! (`Sae::ts_value`, `IscArray::read`) keep the closed form as the
//! reference fallback.
//!
//! Beyond the table horizon (`bins · step_us`, chosen ≥ the memory window
//! K·τ) a cell's value is defined as exactly `0.0`. This is what makes
//! the activity-aware readout ([`crate::util::active::ActiveSet`])
//! bit-for-bit equal to a dense scan: a pixel older than the horizon can
//! be dropped from the active set without changing any frame.

/// Default quantization step: 50 µs (≤ 3.4 mV error on the ISC decay
/// bank; ≤ `50/τ` relative error on a pure exponential).
pub const DEFAULT_STEP_US: u64 = 50;

/// Memory-horizon factor for exponential kernels: the LUT covers
/// Δt ≤ K·τ with K = 8 (e^{−8} ≈ 3.4·10⁻⁴ — below every quantization
/// floor in the simulator), after which the value reads as exactly 0.
pub const EXP_HORIZON_TAUS: f64 = 8.0;

/// Hard cap on table length so a pathological τ cannot allocate
/// unbounded memory (65 536 bins × 50 µs ≈ 3.3 s horizon).
pub const MAX_BINS: usize = 65_536;

/// A bank of quantized decay curves: `rows` curves × `bins` samples at
/// `step_us` spacing. Row-major, so one curve is one contiguous slice.
#[derive(Clone, Debug)]
pub struct DecayLut {
    rows: usize,
    bins: usize,
    step_us: u64,
    table: Vec<f32>,
}

impl DecayLut {
    /// Tabulate `rows` curves: `f(row, dt_us)` is sampled at
    /// `dt_us = bin · step_us` for every bin.
    pub fn build(
        rows: usize,
        bins: usize,
        step_us: u64,
        mut f: impl FnMut(usize, u64) -> f64,
    ) -> Self {
        assert!(rows > 0 && bins > 0 && step_us > 0, "empty decay LUT");
        let mut table = Vec::with_capacity(rows * bins);
        for row in 0..rows {
            for bin in 0..bins {
                table.push(f(row, bin as u64 * step_us) as f32);
            }
        }
        Self { rows, bins, step_us, table }
    }

    /// (step_us, bins) covering `span_us` of decay: the 50 µs default
    /// step, widened (never truncated) when the span would need more
    /// than [`MAX_BINS`] bins — the horizon always reaches `span_us`,
    /// and the error bound is `step_us(actual)/τ` either way.
    pub fn layout_for_span(span_us: f64) -> (u64, usize) {
        assert!(span_us > 0.0);
        let step = (DEFAULT_STEP_US as f64).max((span_us / MAX_BINS as f64).ceil()) as u64;
        let bins = ((span_us / step as f64).ceil() as usize).clamp(64, MAX_BINS);
        (step, bins)
    }

    /// Single-row pure-exponential kernel `e^{−Δt/τ}` at the default
    /// 50 µs step (widened for τ > 409.6 ms, see
    /// [`DecayLut::layout_for_span`]), with the horizon sized to
    /// [`EXP_HORIZON_TAUS`]·τ.
    pub fn exponential(tau_us: f64) -> Self {
        assert!(tau_us > 0.0);
        let (step, bins) = Self::layout_for_span(EXP_HORIZON_TAUS * tau_us);
        Self::build(1, bins, step, |_, dt_us| (-(dt_us as f64) / tau_us).exp())
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn bins(&self) -> usize {
        self.bins
    }

    /// Resident bytes (struct + sample table) — the serve layer's
    /// `resident_bytes` accounting convention.
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.table.capacity() * std::mem::size_of::<f32>()
    }

    #[inline]
    pub fn step_us(&self) -> u64 {
        self.step_us
    }

    /// Age beyond which every curve reads as exactly 0.
    #[inline]
    pub fn horizon_us(&self) -> u64 {
        self.bins as u64 * self.step_us
    }

    /// One curve as a contiguous slice (bin `k` holds `f(k · step_us)`).
    #[inline]
    pub fn row(&self, row: usize) -> &[f32] {
        &self.table[row * self.bins..(row + 1) * self.bins]
    }

    /// Quantized read at age `dt_us`: the value at `floor(dt/step)·step`
    /// (rounded through f32 storage), or exactly 0 past the horizon.
    #[inline]
    pub fn eval(&self, row: usize, dt_us: u64) -> f64 {
        let bin = (dt_us / self.step_us) as usize;
        if bin >= self.bins {
            0.0
        } else {
            self.table[row * self.bins + bin] as f64
        }
    }

    /// The full readout query: value of a cell last written at `t_write`
    /// (0 = never) observed at `t_us`. Unwritten cells and queries before
    /// the write read 0 — the same contract every `frame_into` obeys.
    #[inline]
    pub fn value(&self, row: usize, t_write: u64, t_us: u64) -> f64 {
        if t_write == 0 || t_us < t_write {
            0.0
        } else {
            self.eval(row, t_us - t_write)
        }
    }

    /// Batched gather over one contiguous run of cells:
    /// `out[k] = value(param[k], t_write[k], t_us)`. The three slices are
    /// parallel views of the same cell run (same length), so the loop is
    /// a straight bounds-free walk the compiler can unroll — the unit of
    /// the run-batched readout inner loop.
    #[inline]
    pub fn fill_run(&self, param: &[u32], t_write: &[u64], t_us: u64, out: &mut [f64]) {
        debug_assert!(param.len() == out.len() && t_write.len() == out.len());
        for ((o, &pi), &tw) in out.iter_mut().zip(param).zip(t_write) {
            *o = self.value(pi as usize, tw, t_us);
        }
    }

    /// Max-merge variant of [`DecayLut::fill_run`]: the value lands only
    /// where it exceeds what is already in `out` (the merged-polarity
    /// readout).
    #[inline]
    pub fn merge_run(&self, param: &[u32], t_write: &[u64], t_us: u64, out: &mut [f64]) {
        debug_assert!(param.len() == out.len() && t_write.len() == out.len());
        for ((o, &pi), &tw) in out.iter_mut().zip(param).zip(t_write) {
            let v = self.value(pi as usize, tw, t_us);
            if v > *o {
                *o = v;
            }
        }
    }

    /// Single-curve (`row == 0`) variant of [`DecayLut::fill_run`] for
    /// representations with one shared decay kernel.
    #[inline]
    pub fn fill_run_single(&self, t_write: &[u64], t_us: u64, out: &mut [f64]) {
        debug_assert_eq!(t_write.len(), out.len());
        for (o, &tw) in out.iter_mut().zip(t_write) {
            *o = self.value(0, tw, t_us);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_exact_at_bin_edges() {
        let tau = 10_000.0;
        let lut = DecayLut::exponential(tau);
        // dt a multiple of the step ⇒ the LUT holds the closed form up to
        // the f32 storage rounding (≤6e-8 relative on values ≤ 1).
        for dt in [0u64, 50, 5_000, 10_000, 20_000] {
            let exact = (-(dt as f64) / tau).exp();
            assert!((lut.eval(0, dt) - exact).abs() < 1e-7, "dt={dt}");
        }
    }

    #[test]
    fn exponential_error_bounded_by_step_over_tau() {
        let tau = 10_000.0;
        let lut = DecayLut::exponential(tau);
        let bound = lut.step_us() as f64 / tau;
        for dt in (0..lut.horizon_us()).step_by(37) {
            let exact = (-(dt as f64) / tau).exp();
            let got = lut.eval(0, dt);
            // Floor-binning over-reads a monotone decay; only the f32
            // storage rounding can under-read, and only marginally.
            assert!(got >= exact - 1e-7, "dt={dt}");
            assert!(got - exact <= bound + 1e-7, "dt={dt}: err {}", got - exact);
        }
    }

    #[test]
    fn beyond_horizon_reads_exact_zero() {
        let lut = DecayLut::exponential(1_000.0);
        assert_eq!(lut.eval(0, lut.horizon_us()), 0.0);
        assert_eq!(lut.eval(0, u64::MAX), 0.0);
    }

    #[test]
    fn value_contract_unwritten_and_future() {
        let lut = DecayLut::exponential(1_000.0);
        assert_eq!(lut.value(0, 0, 500), 0.0, "never written");
        assert_eq!(lut.value(0, 1_000, 500), 0.0, "query precedes write");
        assert_eq!(lut.value(0, 500, 500), 1.0, "fresh write");
    }

    #[test]
    fn multi_row_layout_contiguous() {
        let lut = DecayLut::build(3, 4, 10, |row, dt| (row * 100) as f64 + dt as f64);
        assert_eq!(lut.row(1), &[100.0f32, 110.0, 120.0, 130.0]);
        assert_eq!(lut.eval(2, 25), 220.0); // bin 2 of row 2
    }

    #[test]
    fn run_gathers_match_pointwise_value() {
        let lut = DecayLut::build(3, 64, 10, |row, dt| (row as f64 + 1.0) / (1.0 + dt as f64));
        let param = [0u32, 2, 1, 0];
        let t_write = [0u64, 100, 250, 400];
        let t_us = 300u64;
        let mut out = [0.0f64; 4];
        lut.fill_run(&param, &t_write, t_us, &mut out);
        for k in 0..4 {
            assert_eq!(out[k], lut.value(param[k] as usize, t_write[k], t_us), "k={k}");
        }
        // Merge only overwrites where the new value is larger.
        let mut merged = [0.0f64, 10.0, 0.0, 10.0];
        lut.merge_run(&param, &t_write, t_us, &mut merged);
        assert_eq!(merged[0], out[0].max(0.0));
        assert_eq!(merged[1], 10.0);
        assert_eq!(merged[2], out[2]);
        assert_eq!(merged[3], 10.0);
        // Single-curve gather is the row-0 fill.
        let mut single = [0.0f64; 4];
        lut.fill_run_single(&t_write, t_us, &mut single);
        for k in 0..4 {
            assert_eq!(single[k], lut.value(0, t_write[k], t_us), "k={k}");
        }
    }

    #[test]
    fn horizon_scales_with_tau() {
        let short = DecayLut::exponential(200.0);
        let long = DecayLut::exponential(100_000.0);
        assert!(short.horizon_us() >= (EXP_HORIZON_TAUS * 200.0) as u64);
        assert!(long.horizon_us() > short.horizon_us());
        assert!(long.bins() <= MAX_BINS);
    }

    #[test]
    fn huge_tau_widens_step_instead_of_truncating_horizon() {
        // τ = 1 s would need 160 000 bins at 50 µs; the layout must widen
        // the step so the 8τ horizon is still covered.
        let tau = 1_000_000.0;
        let lut = DecayLut::exponential(tau);
        assert!(lut.bins() <= MAX_BINS);
        assert!(lut.step_us() > DEFAULT_STEP_US);
        assert!(lut.horizon_us() as f64 >= EXP_HORIZON_TAUS * tau);
        // A pixel aged 3.3 s must still read its exact-ish value, not 0.
        let dt = 3_300_000u64;
        let exact = (-(dt as f64) / tau).exp();
        let got = lut.eval(0, dt);
        assert!(got > 0.0);
        assert!(got - exact <= lut.step_us() as f64 / tau + 1e-7 && got >= exact - 1e-7);
    }
}
