//! Deterministic pseudo-random number generation.
//!
//! The crates.io `rand` family is unavailable in this offline build, so the
//! repository carries its own small, well-tested generator: PCG64 (XSL-RR
//! 128/64), the same algorithm used by `rand_pcg::Pcg64`. Every stochastic
//! component in the simulator (Monte Carlo mismatch, Poisson noise, scene
//! motion, dataset shuffling) takes an explicit seed so that experiments are
//! exactly reproducible run-to-run.

/// PCG64 XSL-RR 128/64 generator.
///
/// 128-bit LCG state advanced with the standard PCG multiplier, output via
/// xor-shift-low + random rotate. Passes practrand at the sizes used here.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream selector; distinct streams
    /// are statistically independent even for equal seeds.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        // SplitMix64 expansion of the seed into 128 bits of state, matching
        // the common practice for seeding wide-state generators.
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let inc = (((stream as u128) << 64 | sm.next_u64() as u128) << 1) | 1;
        let mut rng = Self { state: (s0 << 64) | s1, inc };
        // Standard PCG warm-up.
        rng.state = rng.state.wrapping_add(rng.inc);
        let _ = rng.next_u64();
        rng
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let state = self.state;
        self.state = state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        // XSL-RR output function.
        let xored = ((state >> 64) as u64) ^ (state as u64);
        let rot = (state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform double in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random bits into the mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform double in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar form avoided to stay branchless
    /// enough for the MC loops; trig form is fine at our call rates).
    pub fn normal(&mut self) -> f64 {
        // Guard u1 away from 0 so ln() is finite.
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/σ.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal such that the *median* is `median` and σ of ln is `sigma_ln`.
    /// Used for leakage-current mismatch, which is lognormal to first order
    /// (exponential dependence on threshold-voltage mismatch).
    #[inline]
    pub fn lognormal(&mut self, median: f64, sigma_ln: f64) -> f64 {
        median * (sigma_ln * self.normal()).exp()
    }

    /// Poisson draw (Knuth for small λ, normal approximation for large λ).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let v = self.normal_ms(lambda, lambda.sqrt()).round();
            if v < 0.0 { 0 } else { v as u64 }
        }
    }

    /// Exponential inter-arrival draw with rate λ (events per unit time).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// SplitMix64 — used only for seed expansion.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::new(3);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut r = Pcg64::new(9);
        for &lambda in &[0.5, 5.0, 80.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < 0.1 * lambda.max(1.0),
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(17);
        let s = r.sample_indices(100, 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn lognormal_median() {
        let mut r = Pcg64::new(19);
        let n = 50_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(3.0, 0.5)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[n / 2];
        assert!((med - 3.0).abs() < 0.1, "median={med}");
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::with_stream(42, 1);
        let mut b = Pcg64::with_stream(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
