//! Set-associative sparse recency store — O(m) session memory for
//! spatiotemporal filters (ROADMAP open item 4).
//!
//! Every dense backend in this crate keys state by pixel index into an
//! O(H·W) plane, even when only a handful of pixels have fired recently.
//! Zhao et al.'s cache-like DVS denoise filter (arXiv 2410.12423) shows
//! the state a spatiotemporal support test actually needs is bounded by
//! recent *activity*, not sensor area. [`SparseRecencyStore`] is that
//! store: a fixed budget of (key → last timestamp) entries organised as
//! a power-of-two number of sets with a bounded number of ways per set,
//! hashed by pixel key.
//!
//! ## Eviction guarantee (the bounded-undercount law)
//!
//! Within a set, insertion evicts the entry with the **minimum** stored
//! timestamp — so an evicted entry is provably older than every entry
//! retained in its set at eviction time. A reader that misses therefore
//! only ever under-reads *older* activity: for any query window, a probe
//! that would have matched the evicted entry is at least as old as the
//! set's retained minimum was, which bounds the undercount of
//! [`crate::denoise::support_count`] to events older than everything the
//! cache kept. While the working set fits (no set overflows its ways),
//! reads are bit-for-bit identical to the dense store — see
//! `tests/sparse_equiv.rs`.
//!
//! Lookup and insert are O(ways) probes with one hash — the
//! "O(window) probes" cost model of the cache STCF backend.

/// Pack a (plane, x, y) pixel coordinate into a store key. `plane`
/// distinguishes polarity surfaces (0 = single/ON, 1 = OFF), mirroring
/// the dense backends' per-polarity planes.
#[inline]
pub fn pixel_key(plane: u8, x: u16, y: u16) -> u64 {
    ((plane as u64) << 32) | ((y as u64) << 16) | x as u64
}

/// SplitMix64 finalizer: a full-avalanche 64-bit mix, the same family
/// the ISC mismatch assignment uses ([`crate::isc::param_index_at`]).
#[inline]
fn hash64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// One cache slot: `t == 0` means empty (the crate-wide "never written"
/// sentinel — writers store `t.max(1)`, exactly like the dense SAE).
#[derive(Clone, Copy, Default)]
struct Slot {
    key: u64,
    t: u64,
}

/// Bounded set-associative map from pixel key to last event timestamp.
///
/// Capacity is fixed at construction (`sets × ways` slots, sets rounded
/// up to a power of two); memory never grows with sensor resolution or
/// stream length. See the module docs for the eviction guarantee.
pub struct SparseRecencyStore {
    slots: Vec<Slot>,
    set_mask: u64,
    ways: usize,
    len: usize,
    evictions: u64,
}

impl SparseRecencyStore {
    /// Store holding at least `min_entries` slots organised as sets of
    /// `ways`. The set count rounds up to a power of two, so the real
    /// capacity may exceed `min_entries` by up to 2×.
    pub fn new(min_entries: usize, ways: usize) -> Self {
        let ways = ways.max(1);
        let sets = min_entries.div_ceil(ways).next_power_of_two().max(1);
        Self {
            slots: vec![Slot::default(); sets * ways],
            set_mask: sets as u64 - 1,
            ways,
            len: 0,
            evictions: 0,
        }
    }

    #[inline]
    fn set_base(&self, key: u64) -> usize {
        ((hash64(key) & self.set_mask) as usize) * self.ways
    }

    /// Last recorded timestamp for `key`, or `None` on a miss (never
    /// written, or written and since evicted).
    #[inline]
    pub fn last(&self, key: u64) -> Option<u64> {
        let base = self.set_base(key);
        self.slots[base..base + self.ways]
            .iter()
            .find(|s| s.t != 0 && s.key == key)
            .map(|s| s.t)
    }

    /// Record an event at `key`. Overwrites in place on a hit (latest
    /// write wins, like the dense SAE), fills an empty way otherwise,
    /// and past that evicts the set's **oldest** entry — the bounded-
    /// undercount guarantee in the module docs.
    pub fn mark(&mut self, key: u64, t_us: u64) {
        let t = t_us.max(1);
        let base = self.set_base(key);
        let set = &mut self.slots[base..base + self.ways];
        if let Some(s) = set.iter_mut().find(|s| s.t != 0 && s.key == key) {
            s.t = t;
            return;
        }
        if let Some(s) = set.iter_mut().find(|s| s.t == 0) {
            *s = Slot { key, t };
            self.len += 1;
            return;
        }
        let mut victim = 0;
        for (i, s) in set.iter().enumerate().skip(1) {
            if s.t < set[victim].t {
                victim = i;
            }
        }
        debug_assert!(set.iter().all(|s| s.t >= set[victim].t));
        set[victim] = Slot { key, t };
        self.evictions += 1;
    }

    /// Live entries.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no entry is live.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total slots (sets × ways) — the fixed memory budget.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Ways per set.
    #[inline]
    pub fn ways(&self) -> usize {
        self.ways
    }

    /// Entries displaced so far (0 ⇔ every read so far was bit-for-bit
    /// equivalent to a dense store).
    #[inline]
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Visit every resident entry as `f(key, last_write_us)` in slot
    /// order — the checkpoint export walk of `serve::supervise`.
    /// [`pixel_key`] is invertible (`plane = key >> 32`,
    /// `y = (key >> 16) & 0xFFFF`, `x = key & 0xFFFF`), and re-`mark`ing
    /// the visited entries on an identically shaped store reproduces
    /// every [`SparseRecencyStore::last`] answer (victim selection is by
    /// minimum stamp, so slot order within a set is not observable).
    pub fn for_each_entry(&self, mut f: impl FnMut(u64, u64)) {
        for s in &self.slots {
            if s.t != 0 {
                f(s.key, s.t);
            }
        }
    }

    /// Drop every entry; capacity is retained.
    pub fn clear(&mut self) {
        self.slots.fill(Slot::default());
        self.len = 0;
        self.evictions = 0;
    }

    /// Resident heap + struct bytes (exact for this type: the slot
    /// vector never reallocates).
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.slots.len() * std::mem::size_of::<Slot>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_overwrite() {
        let mut s = SparseRecencyStore::new(64, 4);
        let k = pixel_key(0, 3, 7);
        assert_eq!(s.last(k), None);
        s.mark(k, 100);
        assert_eq!(s.last(k), Some(100));
        s.mark(k, 250);
        assert_eq!(s.last(k), Some(250), "latest write wins in place");
        assert_eq!(s.len(), 1);
        assert_eq!(s.evictions(), 0);
    }

    #[test]
    fn zero_timestamp_is_clamped_like_the_dense_sae() {
        let mut s = SparseRecencyStore::new(16, 2);
        s.mark(pixel_key(0, 0, 0), 0);
        assert_eq!(s.last(pixel_key(0, 0, 0)), Some(1));
    }

    #[test]
    fn plane_bit_separates_polarity_surfaces() {
        let mut s = SparseRecencyStore::new(64, 4);
        s.mark(pixel_key(0, 5, 5), 10);
        s.mark(pixel_key(1, 5, 5), 20);
        assert_eq!(s.last(pixel_key(0, 5, 5)), Some(10));
        assert_eq!(s.last(pixel_key(1, 5, 5)), Some(20));
    }

    #[test]
    fn eviction_removes_the_sets_oldest_entry() {
        // 1 set × 2 ways: the third distinct key must evict, and the
        // victim must be the older of the two residents.
        let mut s = SparseRecencyStore::new(2, 2);
        assert_eq!(s.capacity(), 2);
        let (a, b, c) = (pixel_key(0, 1, 0), pixel_key(0, 2, 0), pixel_key(0, 3, 0));
        s.mark(a, 100);
        s.mark(b, 900);
        s.mark(c, 500);
        assert_eq!(s.evictions(), 1);
        assert_eq!(s.last(a), None, "oldest entry (t=100) must be the victim");
        assert_eq!(s.last(b), Some(900));
        assert_eq!(s.last(c), Some(500));
        // The retained minimum (500) exceeds the evicted stamp (100):
        // the bounded-undercount law.
    }

    #[test]
    fn capacity_is_fixed_and_len_bounded() {
        let mut s = SparseRecencyStore::new(100, 4);
        let cap = s.capacity();
        assert!(cap >= 100 && cap.is_power_of_two() || (cap / 4).is_power_of_two());
        let bytes = s.approx_bytes();
        for k in 0..10_000u64 {
            s.mark(pixel_key(0, (k % 640) as u16, (k / 640) as u16), 1 + k);
        }
        assert!(s.len() <= cap);
        assert_eq!(s.approx_bytes(), bytes, "memory never grows");
        assert!(s.evictions() > 0);
    }

    #[test]
    fn clear_empties_but_keeps_capacity() {
        let mut s = SparseRecencyStore::new(32, 4);
        s.mark(pixel_key(0, 1, 1), 7);
        assert!(!s.is_empty());
        let cap = s.capacity();
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert_eq!(s.capacity(), cap);
        assert_eq!(s.last(pixel_key(0, 1, 1)), None);
    }
}
