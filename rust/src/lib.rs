//! # tsisc — 3D Stack In-Sensor-Computing for Time-Surface Construction
//!
//! Full-system reproduction of "3D Stack In-Sensor-Computing (3DS-ISC):
//! Accelerating Time-Surface Construction for Neuromorphic Event Cameras"
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Rust (this crate)** — event streaming, the SPICE-substitute circuit
//!   simulator, 2D/3D architecture models, the ISC analog-array simulator,
//!   time-surface representations, the STCF denoiser, the event-pipeline
//!   coordinator and the PJRT runtime executing AOT-compiled JAX/Pallas
//!   artifacts on the hot path.
//! * **JAX/Pallas (build time)** — time-surface kernels and the CNN/UNet
//!   models, lowered once to `artifacts/*.hlo.txt` by `make artifacts`.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Migration: `Representation` → `EventSink` + `FrameSource`
//!
//! The ingestion/readout API is batch-first as of the streaming-API
//! redesign. The monolithic `Representation::update(&Event)` /
//! `frame(t) -> Grid` trait was split into two layered traits (see
//! [`tsurface::traits`]):
//!
//! | old                          | new                                           |
//! |------------------------------|-----------------------------------------------|
//! | `rep.update(&e)`             | [`tsurface::EventSink::ingest`]`(&e)`         |
//! | per-event loops              | [`tsurface::EventSink::ingest_batch`]`(&[Event])` |
//! | `rep.frame(t)` (allocating)  | unchanged, or [`tsurface::FrameSource::frame_into`] with a reused buffer |
//! | `pipeline::run(&[..], …)`    | `pipeline::run(events.iter().copied(), …)` — any `IntoIterator<Item = LabeledEvent>` |
//!
//! `tsurface::Representation` still exists as the combined object-safe
//! trait (`EventSink + FrameSource` plus `name`/`memory_bits`) for
//! heterogeneous comparison tables. Bulk producers should batch:
//! `Router::route_batch`, `IscArray::write_batch` and the coordinator
//! pipeline all move events in batches end to end.
//!
//! Readout is activity-aware and transcendental-free as of the
//! activity-aware readout change: decaying surfaces evaluate through the
//! shared quantized [`util::decay::DecayLut`] and `frame_into` touches
//! only pixels listed in the per-row [`util::active::ActiveSet`] —
//! O(active) per frame instead of O(H·W). See the [`tsurface`] and
//! [`isc`] module docs for the per-path complexity tables.
//!
//! Many concurrent camera streams multiplex over one fixed worker fleet
//! through the [`serve`] session layer (`SessionManager`): per-session
//! pipelines as queued (session, band) jobs with admission control and
//! fair round-robin scheduling, frames bit-for-bit identical to a
//! dedicated [`coordinator`] pipeline of the same stream.

pub mod arch;
pub mod circuit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod denoise;
pub mod events;
pub mod experiments;
pub mod isc;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod recon;
pub mod runtime;
pub mod serve;
pub mod train;
pub mod tsurface;
pub mod util;
