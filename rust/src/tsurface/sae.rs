//! Timestamp-based representations: SAE (Eq. 2), the ideal exponential
//! time-surface (Eq. 3/5), and the finite-width "digital SRAM" variant
//! exhibiting the timestamp-overflow hazard the paper's analog array avoids.
//!
//! Readout is activity-aware and transcendental-free: the SAE keeps
//! per-row active-pixel lists ([`ActiveSet`]) so `frame_into` zero-fills
//! once and then touches only written pixels, and the exponential kernel
//! is evaluated through the shared quantized [`DecayLut`] (no `exp()` in
//! any frame loop). Large frames render row-parallel on scoped threads
//! (`frame_into_chunks`, bit-for-bit identical for every chunk count),
//! the inner loops gather over sorted contiguous column runs, and above
//! [`DENSE_FALLBACK_ALPHA`] activity the render falls back to a dense
//! row scan automatically. Dense reference scans are kept as
//! `frame_dense_into` for the equivalence tests and the dense-vs-active
//! benchmarks.

use super::traits::{EventSink, FrameSource, Representation};
use crate::events::{Event, Resolution};
use crate::util::active::{for_each_sorted_run, ActiveSet, DENSE_FALLBACK_ALPHA};
use crate::util::bitplane::RecencyPlane;
use crate::util::decay::DecayLut;
use crate::util::grid::Grid;
use crate::util::parallel::{auto_chunks, for_each_row_chunk};

/// Surface of Active Events: per-pixel latest timestamp (full precision).
pub struct Sae {
    res: Resolution,
    /// Last event time per pixel (µs; 0 = never).
    t: Vec<u64>,
    /// Written-pixel lists per row. Full-precision timestamps never
    /// expire, so this set only grows (and is exactly the written set).
    active: ActiveSet,
    /// Optional per-row recency bitmask, maintained on every write
    /// (see [`Sae::with_recency`]). Backs the STCF bitmask support scan.
    recency: Option<RecencyPlane>,
    events: u64,
    writes: u64,
}

impl Sae {
    pub fn new(res: Resolution) -> Self {
        Self {
            res,
            t: vec![0; res.pixels()],
            active: ActiveSet::new(res.width as usize, res.height as usize),
            recency: None,
            events: 0,
            writes: 0,
        }
    }

    /// SAE that additionally maintains a [`RecencyPlane`] on every write,
    /// guaranteeing no false negatives for recency windows up to
    /// `window_us` — the backing store of the STCF bitmask support scan
    /// (see [`crate::denoise::support_count`]).
    pub fn with_recency(res: Resolution, window_us: u64) -> Self {
        let mut s = Self::new(res);
        s.recency = Some(RecencyPlane::new(res.width as usize, res.height as usize, window_us));
        s
    }

    /// The recency bitmask plane, if this SAE maintains one.
    #[inline]
    pub fn recency(&self) -> Option<&RecencyPlane> {
        self.recency.as_ref()
    }

    /// Raw timestamp read (the SAE value).
    #[inline]
    pub fn last(&self, x: u16, y: u16) -> u64 {
        self.t[self.res.index(x, y)]
    }

    /// Ideal TS value at query time: e^{−(t−SAE)/τ} (Eq. 5), 0 if
    /// unwritten. This is the *exact* closed form — the reference the
    /// quantized [`DecayLut`] paths are tested against.
    #[inline]
    pub fn ts_value(&self, x: u16, y: u16, t_us: u64, tau_us: f64) -> f64 {
        let tw = self.last(x, y);
        if tw == 0 || t_us < tw {
            0.0
        } else {
            (-((t_us - tw) as f64) / tau_us).exp()
        }
    }

    /// Row-sliced support scan: how many pixels in `x0..=x1` of row `y`
    /// hold an event within `tau_tw_us` of `t_us`? One contiguous slice
    /// walk — the STCF patch query uses one call per patch row.
    pub fn count_recent_in_row(&self, y: u16, x0: u16, x1: u16, t_us: u64, tau_tw_us: u64) -> u32 {
        debug_assert!(x0 <= x1 && self.res.contains(x1, y));
        let start = self.res.index(x0, y);
        let end = self.res.index(x1, y);
        let mut n = 0u32;
        for &tw in &self.t[start..=end] {
            if tw != 0 && t_us >= tw && t_us - tw <= tau_tw_us {
                n += 1;
            }
        }
        n
    }

    /// Resident bytes of this SAE (timestamp plane + active set +
    /// optional recency plane) — one leaf of the serve layer's
    /// `resident_bytes` gauge. O(H·W) by construction: the dense term
    /// the sparse STCF backend ([`crate::util::sparse`]) avoids.
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.t.capacity() * std::mem::size_of::<u64>()
            + self.active.approx_bytes()
            + self.recency.as_ref().map_or(0, |rp| rp.approx_bytes())
    }

    /// Visit every written stamp as `f(x, y, t)` in row-major order —
    /// the checkpoint export walk of `serve::supervise`. Replaying the
    /// stamps as synthetic events through [`EventSink::ingest`] in
    /// ascending-`t` order rebuilds the timestamp plane, the active set
    /// and the recency bitmask exactly (stamps are already `max(1)`-
    /// clamped on write, so replay is a fixed point).
    pub fn for_each_stamp(&self, mut f: impl FnMut(u16, u16, u64)) {
        let w = self.res.width as usize;
        for (i, &t) in self.t.iter().enumerate() {
            if t != 0 {
                f((i % w) as u16, (i / w) as u16, t);
            }
        }
    }

    /// Dense reference readout: the full-H·W scan `frame_into` is proven
    /// bit-for-bit equivalent to (see `tests/readout_equiv.rs`).
    pub fn frame_dense_into(&self, out: &mut Grid<f64>, _t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let max = *self.t.iter().max().unwrap_or(&1);
        let min_written = self.t.iter().copied().filter(|&t| t > 0).min().unwrap_or(0);
        let span = (max - min_written).max(1) as f64;
        let s = out.as_mut_slice();
        for (o, &t) in s.iter_mut().zip(&self.t) {
            *o = if t == 0 { 0.0 } else { (t - min_written) as f64 / span };
        }
    }
}

impl EventSink for Sae {
    fn ingest(&mut self, e: &Event) {
        let i = self.res.index(e.x, e.y);
        self.t[i] = e.t.max(1);
        self.active.mark(e.x, e.y);
        if let Some(rp) = &mut self.recency {
            rp.mark(e.x, e.y, e.t.max(1));
        }
        self.events += 1;
        self.writes += 1;
    }

    /// Batched inner loop: one bounds-free pass over the slice;
    /// accounting is identical to repeated [`Self::ingest`].
    fn ingest_batch(&mut self, events: &[Event]) {
        if let Some(rp) = &mut self.recency {
            for e in events {
                rp.mark(e.x, e.y, e.t.max(1));
            }
        }
        for e in events {
            let i = self.res.index(e.x, e.y);
            self.t[i] = e.t.max(1);
            self.active.mark(e.x, e.y);
        }
        self.events += events.len() as u64;
        self.writes += events.len() as u64;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl Sae {
    /// [`FrameSource::frame_into`] with an explicit row-chunk count:
    /// chunks render on scoped threads over disjoint row slabs and the
    /// result is bit-for-bit identical for every chunk count (the
    /// normalization bounds are computed once, before chunking).
    pub fn frame_into_chunks(&self, out: &mut Grid<f64>, _t_us: u64, chunks: usize) {
        let (w, h) = (self.res.width as usize, self.res.height as usize);
        out.ensure_shape(w, h, 0.0);
        if self.active.is_empty() {
            out.fill(0.0);
            return;
        }
        // Normalization bounds over the active lists (= the written set).
        let (mut max, mut min_written) = (0u64, u64::MAX);
        for y in 0..h {
            let row_t = &self.t[y * w..(y + 1) * w];
            for &x in self.active.row(y) {
                let t = row_t[x as usize];
                max = max.max(t);
                min_written = min_written.min(t);
            }
        }
        let span = (max - min_written).max(1) as f64;
        let dense = self.active.denser_than(DENSE_FALLBACK_ALPHA);
        let ranges = self.active.render_ranges(dense, chunks);
        let (t_all, active) = (&self.t, &self.active);
        for_each_row_chunk(out, &ranges, |range, slab| {
            if dense {
                // α fallback: one contiguous scan, unwritten pixels are 0.
                for (o, &t) in slab.iter_mut().zip(&t_all[range.start * w..range.end * w]) {
                    *o = if t == 0 { 0.0 } else { (t - min_written) as f64 / span };
                }
                return;
            }
            slab.fill(0.0);
            let mut scratch: Vec<u16> = Vec::new();
            for y in range.clone() {
                let xs = active.row(y);
                if xs.is_empty() {
                    continue;
                }
                let row_t = &t_all[y * w..(y + 1) * w];
                let row_out = &mut slab[(y - range.start) * w..(y - range.start + 1) * w];
                for_each_sorted_run(xs, &mut scratch, |run| {
                    let src = &row_t[run.clone()];
                    for (o, &t) in row_out[run].iter_mut().zip(src) {
                        *o = (t - min_written) as f64 / span;
                    }
                });
            }
        });
    }
}

impl FrameSource for Sae {
    /// Frame = timestamps min-max normalized (the Fig. 6a view).
    /// O(active): min/max and the value pass walk only written pixels,
    /// with the dense fallback above [`DENSE_FALLBACK_ALPHA`] activity
    /// and row-parallel rendering on large frames.
    fn frame_into(&self, out: &mut Grid<f64>, t_us: u64) {
        self.frame_into_chunks(out, t_us, auto_chunks(self.res.pixels()));
    }
}

impl Representation for Sae {
    fn name(&self) -> &'static str {
        "SAE"
    }

    fn memory_bits(&self) -> u64 {
        // Unbounded in theory; a practical system stores ≥ n_T-bit stamps.
        self.res.pixels() as u64 * 64
    }
}

/// Ideal exponential time-surface built on a full-precision SAE.
///
/// Readout (point reads and frames) goes through the shared quantized
/// [`DecayLut`]: 50 µs bins, value error ≤ `step/τ`, and exactly 0 past
/// the `8τ` memory horizon. [`Sae::ts_value`] remains the exact closed
/// form for callers that need it.
///
/// Unlike the backing SAE (whose written set never expires — its frame
/// normalizes raw timestamps), the TS keeps its *own* active set pruned
/// against the decay horizon on the write path, so `frame_into` is
/// O(pixels live within 8τ), not O(pixels ever written).
pub struct IdealTs {
    sae: Sae,
    pub tau_us: f64,
    lut: DecayLut,
    /// Pixels within the decay horizon (lazily pruned, unlike `sae.active`).
    active: ActiveSet,
    /// Latest event time ingested (the prune clock).
    clock_us: u64,
}

impl IdealTs {
    pub fn new(res: Resolution, tau_us: f64) -> Self {
        assert!(tau_us > 0.0);
        Self {
            sae: Sae::new(res),
            tau_us,
            lut: DecayLut::exponential(tau_us),
            active: ActiveSet::new(res.width as usize, res.height as usize),
            clock_us: 0,
        }
    }

    /// Accrue `writes` toward the amortized expiry scan of the TS active
    /// set (see [`crate::util::active::ActiveSet::maybe_prune_expired`]).
    fn maybe_prune(&mut self, writes: usize) {
        let horizon = self.lut.horizon_us();
        let clock = self.clock_us;
        self.active.maybe_prune_expired(writes, &self.sae.t, clock, horizon);
    }

    /// Quantized point read — identical to the corresponding
    /// [`FrameSource::frame_into`] cell (same LUT, same horizon) for
    /// causal queries (`t_us` ≥ the latest ingested event time). Behind
    /// the stream head the frame may already have pruned a pixel this
    /// read still sees (see [`crate::util::active`]).
    #[inline]
    pub fn value(&self, x: u16, y: u16, t_us: u64) -> f64 {
        self.lut.value(0, self.sae.last(x, y), t_us)
    }

    pub fn sae(&self) -> &Sae {
        &self.sae
    }

    /// Age beyond which a pixel reads exactly 0 (the K·τ memory window).
    pub fn memory_horizon_us(&self) -> u64 {
        self.lut.horizon_us()
    }

    /// Dense reference readout (full H·W scan through the same LUT).
    pub fn frame_dense_into(&self, out: &mut Grid<f64>, t_us: u64) {
        let w = self.sae.res.width as usize;
        out.ensure_shape(w, self.sae.res.height as usize, 0.0);
        let s = out.as_mut_slice();
        for (o, &tw) in s.iter_mut().zip(&self.sae.t) {
            *o = self.lut.value(0, tw, t_us);
        }
    }
}

impl EventSink for IdealTs {
    fn ingest(&mut self, e: &Event) {
        self.sae.ingest(e);
        self.active.mark(e.x, e.y);
        self.clock_us = self.clock_us.max(e.t);
        self.maybe_prune(1);
    }

    fn ingest_batch(&mut self, events: &[Event]) {
        self.sae.ingest_batch(events);
        for e in events {
            self.active.mark(e.x, e.y);
        }
        if let Some(t_max) = events.iter().map(|e| e.t).max() {
            self.clock_us = self.clock_us.max(t_max);
        }
        self.maybe_prune(events.len());
    }

    fn memory_writes(&self) -> u64 {
        self.sae.memory_writes()
    }

    fn events_seen(&self) -> u64 {
        self.sae.events_seen()
    }

    fn resolution(&self) -> Resolution {
        self.sae.res
    }
}

impl IdealTs {
    /// [`FrameSource::frame_into`] with an explicit row-chunk count
    /// (bit-for-bit identical for every chunk count; see
    /// [`Sae::frame_into_chunks`]).
    pub fn frame_into_chunks(&self, out: &mut Grid<f64>, t_us: u64, chunks: usize) {
        let (w, h) = (self.sae.res.width as usize, self.sae.res.height as usize);
        out.ensure_shape(w, h, 0.0);
        let dense = self.active.denser_than(DENSE_FALLBACK_ALPHA);
        let ranges = self.active.render_ranges(dense, chunks);
        let (t_all, active, lut) = (&self.sae.t, &self.active, &self.lut);
        for_each_row_chunk(out, &ranges, |range, slab| {
            if dense {
                // α fallback: one batched LUT gather over the whole slab.
                lut.fill_run_single(&t_all[range.start * w..range.end * w], t_us, slab);
                return;
            }
            slab.fill(0.0);
            let mut scratch: Vec<u16> = Vec::new();
            for y in range.clone() {
                let xs = active.row(y);
                if xs.is_empty() {
                    continue;
                }
                let row_t = &t_all[y * w..(y + 1) * w];
                let row_out = &mut slab[(y - range.start) * w..(y - range.start + 1) * w];
                for_each_sorted_run(xs, &mut scratch, |run| {
                    lut.fill_run_single(&row_t[run.clone()], t_us, &mut row_out[run]);
                });
            }
        });
    }
}

impl FrameSource for IdealTs {
    /// O(active) readout: zero-fill, then evaluate the LUT only on
    /// pixels live within the decay horizon (expired ones contribute
    /// the 0 already written by the fill), as sorted-run batched LUT
    /// gathers, row-parallel on large frames, with the dense fallback
    /// above [`DENSE_FALLBACK_ALPHA`] activity. Identical to
    /// [`IdealTs::frame_dense_into`] for every `t_us` ≥ the latest
    /// ingested event time (see [`crate::util::active`] for the
    /// behind-the-stream-head caveat).
    fn frame_into(&self, out: &mut Grid<f64>, t_us: u64) {
        self.frame_into_chunks(out, t_us, auto_chunks(self.sae.res.pixels()));
    }
}

impl Representation for IdealTs {
    fn name(&self) -> &'static str {
        "ideal-TS"
    }

    fn memory_bits(&self) -> u64 {
        self.sae.memory_bits()
    }
}

/// SAE stored in `bits`-wide µs counters — the digital SRAM implementation
/// [26]. The counter wraps, so after 2^bits µs old pixels suddenly look
/// *recent*: the overflow artifact of Sec. II-B / IV-B. Readout shares the
/// quantized exponential [`DecayLut`] (applied to the *wrapped* age, so
/// the aliasing artifact is preserved exactly).
pub struct QuantizedSae {
    res: Resolution,
    bits: u32,
    t: Vec<u64>, // stored wrapped value; u64 for convenience
    written: Vec<bool>,
    pub tau_us: f64,
    lut: DecayLut,
    events: u64,
    writes: u64,
}

impl QuantizedSae {
    pub fn new(res: Resolution, bits: u32, tau_us: f64) -> Self {
        assert!((1..=32).contains(&bits));
        assert!(tau_us > 0.0);
        Self {
            res,
            bits,
            t: vec![0; res.pixels()],
            written: vec![false; res.pixels()],
            tau_us,
            lut: DecayLut::exponential(tau_us),
            events: 0,
            writes: 0,
        }
    }

    #[inline]
    fn mask(&self) -> u64 {
        (1u64 << self.bits) - 1
    }

    /// TS value computed from wrapped stamps — exhibits overflow errors.
    /// Same LUT as the frame path, so point reads ≡ frame cells.
    pub fn value(&self, x: u16, y: u16, t_us: u64) -> f64 {
        let i = self.res.index(x, y);
        if !self.written[i] {
            return 0.0;
        }
        let now = t_us & self.mask();
        // Hardware subtracts modulo 2^bits: an old stamp aliases as recent.
        let dt = now.wrapping_sub(self.t[i]) & self.mask();
        self.lut.eval(0, dt)
    }
}

impl EventSink for QuantizedSae {
    fn ingest(&mut self, e: &Event) {
        let i = self.res.index(e.x, e.y);
        self.t[i] = e.t & self.mask();
        self.written[i] = true;
        self.events += 1;
        self.writes += 1;
    }

    fn ingest_batch(&mut self, events: &[Event]) {
        let mask = self.mask();
        for e in events {
            let i = self.res.index(e.x, e.y);
            self.t[i] = e.t & mask;
            self.written[i] = true;
        }
        self.events += events.len() as u64;
        self.writes += events.len() as u64;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl FrameSource for QuantizedSae {
    fn frame_into(&self, out: &mut Grid<f64>, t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let mask = self.mask();
        let now = t_us & mask;
        let s = out.as_mut_slice();
        for i in 0..s.len() {
            s[i] = if !self.written[i] {
                0.0
            } else {
                self.lut.eval(0, now.wrapping_sub(self.t[i]) & mask)
            };
        }
    }
}

impl Representation for QuantizedSae {
    fn name(&self) -> &'static str {
        "quantized-SAE"
    }

    fn memory_bits(&self) -> u64 {
        self.res.pixels() as u64 * self.bits as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    fn ev(t: u64, x: u16, y: u16) -> Event {
        Event::new(t, x, y, Polarity::On)
    }

    #[test]
    fn sae_keeps_latest() {
        let mut s = Sae::new(Resolution::new(4, 4));
        s.ingest(&ev(100, 1, 1));
        s.ingest(&ev(500, 1, 1));
        assert_eq!(s.last(1, 1), 500);
        assert_eq!(s.writes_per_event(), 1.0);
        // Rewrites do not duplicate the active entry.
        assert_eq!(s.active.len(), 1);
    }

    #[test]
    fn recency_plane_tracks_writes_when_enabled() {
        assert!(Sae::new(Resolution::new(8, 8)).recency().is_none());
        let mut s = Sae::with_recency(Resolution::new(8, 8), 10_000);
        s.ingest(&ev(1_000, 3, 2));
        s.ingest_batch(&[ev(1_500, 5, 2)]);
        let rp = s.recency().unwrap();
        assert!(rp.covers(10_000));
        assert_eq!(rp.popcount_window(2, 0, 7, 2_000), 2);
        assert_eq!(rp.popcount_window(3, 0, 7, 2_000), 0);
    }

    #[test]
    fn sae_batch_equals_single() {
        let evs: Vec<Event> =
            (0..50).map(|k| ev(1 + k * 37, (k % 4) as u16, (k % 3) as u16)).collect();
        let mut one = Sae::new(Resolution::new(4, 4));
        let mut bat = Sae::new(Resolution::new(4, 4));
        for e in &evs {
            one.ingest(e);
        }
        bat.ingest_batch(&evs);
        assert_eq!(one.frame(2_000), bat.frame(2_000));
        assert_eq!(one.events_seen(), bat.events_seen());
        assert_eq!(one.memory_writes(), bat.memory_writes());
    }

    #[test]
    fn sae_active_frame_matches_dense() {
        let mut s = Sae::new(Resolution::new(6, 5));
        s.ingest_batch(&[ev(100, 0, 0), ev(900, 5, 4), ev(400, 2, 3)]);
        let mut dense = Grid::new(1, 1, 0.0);
        s.frame_dense_into(&mut dense, 2_000);
        assert_eq!(s.frame(2_000), dense);
    }

    #[test]
    fn ideal_ts_decays_exponentially() {
        let mut ts = IdealTs::new(Resolution::new(4, 4), 10_000.0);
        ts.ingest(&ev(1_000, 2, 2));
        let v0 = ts.value(2, 2, 1_000);
        let v1 = ts.value(2, 2, 11_000); // one τ later — a LUT bin edge
        assert!((v0 - 1.0).abs() < 1e-12);
        // Bin edge ⇒ only the LUT's f32 storage rounding remains.
        assert!((v1 - (-1.0f64).exp()).abs() < 1e-6);
        // Normalized ≤ 1 always (the paper's bounded-representation point).
        assert!(ts.frame(50_000).as_slice().iter().all(|&v| v <= 1.0));
    }

    #[test]
    fn ideal_ts_frame_into_matches_point_values() {
        let mut ts = IdealTs::new(Resolution::new(4, 4), 10_000.0);
        ts.ingest_batch(&[ev(1_000, 2, 2), ev(3_000, 0, 1)]);
        let mut buf = Grid::new(1, 1, 0.0);
        ts.frame_into(&mut buf, 12_000);
        for x in 0..4u16 {
            for y in 0..4u16 {
                assert_eq!(*buf.get(x as usize, y as usize), ts.value(x, y, 12_000));
            }
        }
    }

    #[test]
    fn ideal_ts_quantization_within_bound() {
        // LUT value vs the exact closed form: error ∈ [0, step/τ].
        let tau = 10_000.0;
        let mut ts = IdealTs::new(Resolution::new(2, 2), tau);
        ts.ingest(&ev(1_000, 0, 0));
        for dt in [0u64, 37, 1_234, 9_999, 25_001] {
            let exact = ts.sae().ts_value(0, 0, 1_000 + dt, tau);
            let got = ts.value(0, 0, 1_000 + dt);
            assert!(got >= exact - 1e-6, "dt={dt}");
            assert!(got - exact <= 50.0 / tau + 1e-6, "dt={dt}: err {}", got - exact);
        }
    }

    #[test]
    fn chunked_frames_identical_for_any_chunk_count() {
        let res = Resolution::new(14, 11);
        let mut sae = Sae::new(res);
        let mut ts = IdealTs::new(res, 12_000.0);
        let evs: Vec<Event> =
            (0..120u64).map(|k| ev(1 + k * 333, (k % 14) as u16, ((k * 3) % 11) as u16)).collect();
        sae.ingest_batch(&evs);
        ts.ingest_batch(&evs);
        let t = evs.last().unwrap().t + 2_500;
        let (mut a, mut b) = (Grid::new(1, 1, 0.0), Grid::new(1, 1, 0.0));
        // 2, 8 and more-chunks-than-rows (11 rows) against the serial render.
        for chunks in [2usize, 8, 64] {
            sae.frame_into_chunks(&mut a, t, 1);
            sae.frame_into_chunks(&mut b, t, chunks);
            assert_eq!(a, b, "sae chunks={chunks}");
            ts.frame_into_chunks(&mut a, t, 1);
            ts.frame_into_chunks(&mut b, t, chunks);
            assert_eq!(a, b, "ideal-ts chunks={chunks}");
        }
    }

    #[test]
    fn dense_fallback_matches_dense_reference_at_full_activity() {
        let res = Resolution::new(12, 9);
        let mut sae = Sae::new(res);
        let mut ts = IdealTs::new(res, 20_000.0);
        // Write every pixel: activity 100 % > α, the fallback must engage
        // and still equal the dense reference scans.
        for y in 0..9u16 {
            for x in 0..12u16 {
                let e = ev(1 + (y as u64 * 12 + x as u64) * 40, x, y);
                sae.ingest(&e);
                ts.ingest(&e);
            }
        }
        assert!(sae.active.denser_than(crate::util::active::DENSE_FALLBACK_ALPHA));
        let t = 1 + 108 * 40 + 777;
        let (mut got, mut want) = (Grid::new(1, 1, 0.0), Grid::new(1, 1, 0.0));
        sae.frame_into(&mut got, t);
        sae.frame_dense_into(&mut want, t);
        assert_eq!(got, want, "sae dense fallback");
        ts.frame_into(&mut got, t);
        ts.frame_dense_into(&mut want, t);
        assert_eq!(got, want, "ideal-ts dense fallback");
    }

    #[test]
    fn quantized_sae_overflow_artifact() {
        // 10-bit µs counter wraps every 1 024 µs: a pixel written at t=1
        // and read at t=1025+1 looks *fresh* again.
        let mut q = QuantizedSae::new(Resolution::new(2, 2), 10, 200.0);
        q.ingest(&ev(1, 0, 0));
        let correct = q.value(0, 0, 900); // Δt=899: ~e^{-4.5}
        let aliased = q.value(0, 0, 1 + 1024 + 10); // wraps: Δt aliases to 10
        assert!(correct < 0.02);
        assert!(aliased > 0.9, "overflow alias expected, got {aliased}");
    }

    #[test]
    fn full_precision_has_no_alias() {
        let mut ts = IdealTs::new(Resolution::new(2, 2), 200.0);
        ts.ingest(&ev(1, 0, 0));
        assert!(ts.value(0, 0, 1 + 1024 + 10) < 0.01);
    }

    #[test]
    fn ideal_ts_active_set_prunes_expired_pixels() {
        // 256 distinct stale pixels, then a rewrite burst confined to an
        // 8×8 region far past the horizon: the write-budget scan must
        // drop the stale 256 while the SAE's written set keeps them all.
        let res = Resolution::new(64, 64);
        let mut ts = IdealTs::new(res, 1_000.0);
        for k in 0..256u64 {
            ts.ingest(&ev(1 + k, (k % 64) as u16, (k / 64) as u16));
        }
        let far = ts.memory_horizon_us() * 3;
        for k in 0..600u64 {
            ts.ingest(&ev(far + k, (k % 8) as u16, (32 + (k / 8) % 8) as u16));
        }
        assert_eq!(ts.active.len(), 64, "expired TS pixels must be pruned");
        assert_eq!(ts.sae.active.len(), 256 + 64, "SAE written set never expires");
        // Readout stays exact after pruning.
        let t = far + 1_000;
        let mut dense = Grid::new(1, 1, 0.0);
        ts.frame_dense_into(&mut dense, t);
        assert_eq!(ts.frame(t), dense);
    }

    #[test]
    fn ideal_ts_zero_past_memory_horizon() {
        let mut ts = IdealTs::new(Resolution::new(2, 2), 1_000.0);
        ts.ingest(&ev(1_000, 1, 1));
        let horizon = ts.memory_horizon_us();
        assert!(ts.value(1, 1, 1_000 + horizon - 1) > 0.0);
        assert_eq!(ts.value(1, 1, 1_000 + horizon), 0.0);
    }

    #[test]
    fn unwritten_pixels_zero_in_all() {
        let res = Resolution::new(3, 3);
        let s = Sae::new(res);
        let ts = IdealTs::new(res, 1e4);
        let q = QuantizedSae::new(res, 16, 1e4);
        assert_eq!(s.frame(100).as_slice().iter().sum::<f64>(), 0.0);
        assert_eq!(ts.frame(100).as_slice().iter().sum::<f64>(), 0.0);
        assert_eq!(q.frame(100).as_slice().iter().sum::<f64>(), 0.0);
    }

    #[test]
    fn count_recent_in_row_matches_point_tests() {
        let res = Resolution::new(8, 3);
        let mut s = Sae::new(res);
        s.ingest_batch(&[ev(100, 1, 1), ev(500, 3, 1), ev(10_000, 6, 1)]);
        // At t=600 with τ_tw=1000: pixels 1 and 3 are recent, 6 is future.
        assert_eq!(s.count_recent_in_row(1, 0, 7, 600, 1_000), 2);
        assert_eq!(s.count_recent_in_row(1, 2, 7, 600, 1_000), 1);
        assert_eq!(s.count_recent_in_row(0, 0, 7, 600, 1_000), 0);
    }
}
