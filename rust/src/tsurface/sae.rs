//! Timestamp-based representations: SAE (Eq. 2), the ideal exponential
//! time-surface (Eq. 3/5), and the finite-width "digital SRAM" variant
//! exhibiting the timestamp-overflow hazard the paper's analog array avoids.

use super::traits::{EventSink, FrameSource, Representation};
use crate::events::{Event, Resolution};
use crate::util::grid::Grid;

/// Surface of Active Events: per-pixel latest timestamp (full precision).
pub struct Sae {
    res: Resolution,
    /// Last event time per pixel (µs; 0 = never).
    t: Vec<u64>,
    events: u64,
    writes: u64,
}

impl Sae {
    pub fn new(res: Resolution) -> Self {
        Self { res, t: vec![0; res.pixels()], events: 0, writes: 0 }
    }

    /// Raw timestamp read (the SAE value).
    #[inline]
    pub fn last(&self, x: u16, y: u16) -> u64 {
        self.t[self.res.index(x, y)]
    }

    /// Ideal TS value at query time: e^{−(t−SAE)/τ} (Eq. 5), 0 if unwritten.
    #[inline]
    pub fn ts_value(&self, x: u16, y: u16, t_us: u64, tau_us: f64) -> f64 {
        let tw = self.last(x, y);
        if tw == 0 || t_us < tw {
            0.0
        } else {
            (-((t_us - tw) as f64) / tau_us).exp()
        }
    }
}

impl EventSink for Sae {
    fn ingest(&mut self, e: &Event) {
        let i = self.res.index(e.x, e.y);
        self.t[i] = e.t.max(1);
        self.events += 1;
        self.writes += 1;
    }

    /// Batched inner loop: one bounds-free pass over the slice with the
    /// stride hoisted; accounting is identical to repeated [`Self::ingest`].
    fn ingest_batch(&mut self, events: &[Event]) {
        let w = self.res.width as usize;
        for e in events {
            debug_assert!(self.res.contains(e.x, e.y));
            self.t[e.y as usize * w + e.x as usize] = e.t.max(1);
        }
        self.events += events.len() as u64;
        self.writes += events.len() as u64;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl FrameSource for Sae {
    /// Frame = timestamps min-max normalized (the Fig. 6a view).
    fn frame_into(&self, out: &mut Grid<f64>, _t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let max = *self.t.iter().max().unwrap_or(&1);
        let min_written = self.t.iter().copied().filter(|&t| t > 0).min().unwrap_or(0);
        let span = (max - min_written).max(1) as f64;
        let s = out.as_mut_slice();
        for (o, &t) in s.iter_mut().zip(&self.t) {
            *o = if t == 0 { 0.0 } else { (t - min_written) as f64 / span };
        }
    }
}

impl Representation for Sae {
    fn name(&self) -> &'static str {
        "SAE"
    }

    fn memory_bits(&self) -> u64 {
        // Unbounded in theory; a practical system stores ≥ n_T-bit stamps.
        self.res.pixels() as u64 * 64
    }
}

/// Ideal exponential time-surface built on a full-precision SAE.
pub struct IdealTs {
    sae: Sae,
    pub tau_us: f64,
}

impl IdealTs {
    pub fn new(res: Resolution, tau_us: f64) -> Self {
        assert!(tau_us > 0.0);
        Self { sae: Sae::new(res), tau_us }
    }

    #[inline]
    pub fn value(&self, x: u16, y: u16, t_us: u64) -> f64 {
        self.sae.ts_value(x, y, t_us, self.tau_us)
    }

    pub fn sae(&self) -> &Sae {
        &self.sae
    }
}

impl EventSink for IdealTs {
    fn ingest(&mut self, e: &Event) {
        self.sae.ingest(e);
    }

    fn ingest_batch(&mut self, events: &[Event]) {
        self.sae.ingest_batch(events);
    }

    fn memory_writes(&self) -> u64 {
        self.sae.memory_writes()
    }

    fn events_seen(&self) -> u64 {
        self.sae.events_seen()
    }

    fn resolution(&self) -> Resolution {
        self.sae.res
    }
}

impl FrameSource for IdealTs {
    fn frame_into(&self, out: &mut Grid<f64>, t_us: u64) {
        let w = self.sae.res.width as usize;
        out.ensure_shape(w, self.sae.res.height as usize, 0.0);
        let tau = self.tau_us;
        let s = out.as_mut_slice();
        for (o, &tw) in s.iter_mut().zip(&self.sae.t) {
            *o = if tw == 0 || t_us < tw {
                0.0
            } else {
                (-((t_us - tw) as f64) / tau).exp()
            };
        }
    }
}

impl Representation for IdealTs {
    fn name(&self) -> &'static str {
        "ideal-TS"
    }

    fn memory_bits(&self) -> u64 {
        self.sae.memory_bits()
    }
}

/// SAE stored in `bits`-wide µs counters — the digital SRAM implementation
/// [26]. The counter wraps, so after 2^bits µs old pixels suddenly look
/// *recent*: the overflow artifact of Sec. II-B / IV-B.
pub struct QuantizedSae {
    res: Resolution,
    bits: u32,
    t: Vec<u64>, // stored wrapped value; u64 for convenience
    written: Vec<bool>,
    pub tau_us: f64,
    events: u64,
    writes: u64,
}

impl QuantizedSae {
    pub fn new(res: Resolution, bits: u32, tau_us: f64) -> Self {
        assert!((1..=32).contains(&bits));
        Self {
            res,
            bits,
            t: vec![0; res.pixels()],
            written: vec![false; res.pixels()],
            tau_us,
            events: 0,
            writes: 0,
        }
    }

    #[inline]
    fn mask(&self) -> u64 {
        (1u64 << self.bits) - 1
    }

    /// TS value computed from wrapped stamps — exhibits overflow errors.
    pub fn value(&self, x: u16, y: u16, t_us: u64) -> f64 {
        let i = self.res.index(x, y);
        if !self.written[i] {
            return 0.0;
        }
        let now = t_us & self.mask();
        let then = self.t[i];
        // Hardware subtracts modulo 2^bits: an old stamp aliases as recent.
        let dt = now.wrapping_sub(then) & self.mask();
        (-(dt as f64) / self.tau_us).exp()
    }
}

impl EventSink for QuantizedSae {
    fn ingest(&mut self, e: &Event) {
        let i = self.res.index(e.x, e.y);
        self.t[i] = e.t & self.mask();
        self.written[i] = true;
        self.events += 1;
        self.writes += 1;
    }

    fn ingest_batch(&mut self, events: &[Event]) {
        let w = self.res.width as usize;
        let mask = self.mask();
        for e in events {
            debug_assert!(self.res.contains(e.x, e.y));
            let i = e.y as usize * w + e.x as usize;
            self.t[i] = e.t & mask;
            self.written[i] = true;
        }
        self.events += events.len() as u64;
        self.writes += events.len() as u64;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl FrameSource for QuantizedSae {
    fn frame_into(&self, out: &mut Grid<f64>, t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let mask = self.mask();
        let now = t_us & mask;
        let tau = self.tau_us;
        let s = out.as_mut_slice();
        for i in 0..s.len() {
            s[i] = if !self.written[i] {
                0.0
            } else {
                let dt = now.wrapping_sub(self.t[i]) & mask;
                (-(dt as f64) / tau).exp()
            };
        }
    }
}

impl Representation for QuantizedSae {
    fn name(&self) -> &'static str {
        "quantized-SAE"
    }

    fn memory_bits(&self) -> u64 {
        self.res.pixels() as u64 * self.bits as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    fn ev(t: u64, x: u16, y: u16) -> Event {
        Event::new(t, x, y, Polarity::On)
    }

    #[test]
    fn sae_keeps_latest() {
        let mut s = Sae::new(Resolution::new(4, 4));
        s.ingest(&ev(100, 1, 1));
        s.ingest(&ev(500, 1, 1));
        assert_eq!(s.last(1, 1), 500);
        assert_eq!(s.writes_per_event(), 1.0);
    }

    #[test]
    fn sae_batch_equals_single() {
        let evs: Vec<Event> = (0..50).map(|k| ev(1 + k * 37, (k % 4) as u16, (k % 3) as u16)).collect();
        let mut one = Sae::new(Resolution::new(4, 4));
        let mut bat = Sae::new(Resolution::new(4, 4));
        for e in &evs {
            one.ingest(e);
        }
        bat.ingest_batch(&evs);
        assert_eq!(one.frame(2_000), bat.frame(2_000));
        assert_eq!(one.events_seen(), bat.events_seen());
        assert_eq!(one.memory_writes(), bat.memory_writes());
    }

    #[test]
    fn ideal_ts_decays_exponentially() {
        let mut ts = IdealTs::new(Resolution::new(4, 4), 10_000.0);
        ts.ingest(&ev(1_000, 2, 2));
        let v0 = ts.value(2, 2, 1_000);
        let v1 = ts.value(2, 2, 11_000); // one τ later
        assert!((v0 - 1.0).abs() < 1e-12);
        assert!((v1 - (-1.0f64).exp()).abs() < 1e-9);
        // Normalized ≤ 1 always (the paper's bounded-representation point).
        assert!(ts.frame(50_000).as_slice().iter().all(|&v| v <= 1.0));
    }

    #[test]
    fn ideal_ts_frame_into_matches_point_values() {
        let mut ts = IdealTs::new(Resolution::new(4, 4), 10_000.0);
        ts.ingest_batch(&[ev(1_000, 2, 2), ev(3_000, 0, 1)]);
        let mut buf = Grid::new(1, 1, 0.0);
        ts.frame_into(&mut buf, 12_000);
        for x in 0..4u16 {
            for y in 0..4u16 {
                assert_eq!(*buf.get(x as usize, y as usize), ts.value(x, y, 12_000));
            }
        }
    }

    #[test]
    fn quantized_sae_overflow_artifact() {
        // 10-bit µs counter wraps every 1 024 µs: a pixel written at t=1
        // and read at t=1025+1 looks *fresh* again.
        let mut q = QuantizedSae::new(Resolution::new(2, 2), 10, 200.0);
        q.ingest(&ev(1, 0, 0));
        let correct = q.value(0, 0, 900); // Δt=899: ~e^{-4.5}
        let aliased = q.value(0, 0, 1 + 1024 + 10); // wraps: Δt aliases to 10
        assert!(correct < 0.02);
        assert!(aliased > 0.9, "overflow alias expected, got {aliased}");
    }

    #[test]
    fn full_precision_has_no_alias() {
        let mut ts = IdealTs::new(Resolution::new(2, 2), 200.0);
        ts.ingest(&ev(1, 0, 0));
        assert!(ts.value(0, 0, 1 + 1024 + 10) < 0.01);
    }

    #[test]
    fn unwritten_pixels_zero_in_all() {
        let res = Resolution::new(3, 3);
        let s = Sae::new(res);
        let ts = IdealTs::new(res, 1e4);
        let q = QuantizedSae::new(res, 16, 1e4);
        assert_eq!(s.frame(100).as_slice().iter().sum::<f64>(), 0.0);
        assert_eq!(ts.frame(100).as_slice().iter().sum::<f64>(), 0.0);
        assert_eq!(q.frame(100).as_slice().iter().sum::<f64>(), 0.0);
    }
}
