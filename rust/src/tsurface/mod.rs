//! 2D event-data representations (paper Sec. II-B) behind one trait:
//! SAE, ideal/quantized time-surfaces, count/binary images, the
//! write-heavy SITS/TOS, the FIFO-based TORE, and the ISC-backed analog
//! time-surface that is this paper's contribution.

pub mod advanced;
pub mod binary;
pub mod isc_ts;
pub mod sae;
pub mod traits;

pub use advanced::{Sits, Tore, Tos};
pub use binary::{Ebbi, EventCount};
pub use isc_ts::IscTs;
pub use sae::{IdealTs, QuantizedSae, Sae};
pub use traits::Representation;
