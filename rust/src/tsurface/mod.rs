//! 2D event-data representations (paper Sec. II-B) behind a layered,
//! batch-first API: SAE, ideal/quantized time-surfaces, count/binary
//! images, the write-heavy SITS/TOS, the FIFO-based TORE, and the
//! ISC-backed analog time-surface that is this paper's contribution.
//!
//! The API is split along the two hardware data paths:
//!
//! * [`EventSink`] — ingestion. `ingest_batch(&[Event])` is the primary
//!   entry point (per-event `ingest` is provided for simple callers);
//!   batches let each representation run a tight, dispatch-free inner
//!   loop, the software analogue of the ISC plane absorbing events in
//!   place.
//! * [`FrameSource`] — readout. `frame_into(&mut Grid<f64>, t_us)`
//!   renders into a caller-owned buffer (zero allocations per frame
//!   after warmup); `frame(t_us)` is the allocating convenience wrapper.
//! * [`Representation`] — the combined trait for heterogeneous
//!   comparison tables (`Box<dyn Representation>`), adding `name`,
//!   `memory_bits` and the writes-per-event accounting.
//!
//! **Migration note** (old → new API): `Representation::update(&e)` →
//! [`EventSink::ingest`] / [`EventSink::ingest_batch`]; `frame(t)` is
//! unchanged for one-shot reads, hot loops should switch to
//! [`FrameSource::frame_into`] with a reused buffer.
//!
//! ## Per-path complexity (activity-aware readout, PR 2)
//!
//! With A = pixels live inside the K·τ memory horizon, W' = pixels ever
//! written, H·W = resolution, r = patch radius. "Before" is the
//! pre-PR-2 dense/transcendental path.
//!
//! | Path | Before | After |
//! |---|---|---|
//! | per-event ingest (SAE-class, ISC) | O(1) | O(1) amortized (+active-list mark) |
//! | per-frame readout (`IdealTs`, ISC) | O(H·W), `exp()`/px | O(A) + one zero-fill, LUT only |
//! | per-frame readout (`Sae`) | O(H·W) | O(W') + one zero-fill (stamps never expire) |
//! | per-frame readout (`QuantizedSae`, `Tore`) | O(H·W), `exp()`/`ln()` | O(H·W), LUT only |
//! | per-STCF-query support scan | (2r+1)² indexed point reads | 2r+1 contiguous row slices |
//!
//! The decay kernels are shared through [`crate::util::decay::DecayLut`]
//! (50 µs quantization, exactly 0 past the K·τ horizon) and the active
//! sets through [`crate::util::active::ActiveSet`]; dense reference
//! scans remain as `frame_dense_into` on `Sae`/`IdealTs`/`IscArray`,
//! proven bit-for-bit equivalent in `tests/readout_equiv.rs`.

pub mod advanced;
pub mod binary;
pub mod isc_ts;
pub mod sae;
pub mod traits;

pub use advanced::{Sits, Tore, Tos};
pub use binary::{Ebbi, EventCount};
pub use isc_ts::IscTs;
pub use sae::{IdealTs, QuantizedSae, Sae};
pub use traits::{ingest_labeled, EventSink, FrameSource, Representation};
