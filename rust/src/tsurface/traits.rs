//! Common interface + resource accounting for 2D event representations
//! (paper Sec. II-B).
//!
//! Every representation ingests events one at a time and can render a
//! frame at any query time. The accounting methods expose the paper's
//! comparison axes: memory footprint (bits) and memory writes per event
//! (SITS/TOS need 25–50× writes, which is why they are hostile to
//! low-energy hardware).

use crate::events::{Event, Resolution};
use crate::util::grid::Grid;

/// A 2D event-stream representation.
pub trait Representation {
    /// Ingest one event (stream order).
    fn update(&mut self, e: &Event);

    /// Render the representation as a [0, 1] frame at query time `t_us`.
    fn frame(&self, t_us: u64) -> Grid<f64>;

    /// Human-readable name.
    fn name(&self) -> &'static str;

    /// Storage footprint in bits for the whole array.
    fn memory_bits(&self) -> u64;

    /// Total memory write operations performed so far (cells touched).
    fn memory_writes(&self) -> u64;

    /// Events ingested so far.
    fn events_seen(&self) -> u64;

    /// Memory writes per event — the paper's key hardware-cost metric.
    fn writes_per_event(&self) -> f64 {
        if self.events_seen() == 0 {
            0.0
        } else {
            self.memory_writes() as f64 / self.events_seen() as f64
        }
    }

    /// Start a new accumulation window. Decay-based surfaces carry state
    /// across windows (like the hardware) — default no-op; per-window
    /// accumulators (count/binary images) clear themselves here.
    fn reset_window(&mut self) {}

    fn resolution(&self) -> Resolution;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    struct Dummy {
        res: Resolution,
        n: u64,
    }
    impl Representation for Dummy {
        fn update(&mut self, _e: &Event) {
            self.n += 1;
        }
        fn frame(&self, _t: u64) -> Grid<f64> {
            Grid::new(1, 1, 0.0)
        }
        fn name(&self) -> &'static str {
            "dummy"
        }
        fn memory_bits(&self) -> u64 {
            8
        }
        fn memory_writes(&self) -> u64 {
            3 * self.n
        }
        fn events_seen(&self) -> u64 {
            self.n
        }
        fn resolution(&self) -> Resolution {
            self.res
        }
    }

    #[test]
    fn writes_per_event_ratio() {
        let mut d = Dummy { res: Resolution::new(2, 2), n: 0 };
        assert_eq!(d.writes_per_event(), 0.0);
        d.update(&Event::new(1, 0, 0, Polarity::On));
        d.update(&Event::new(2, 0, 0, Polarity::On));
        assert_eq!(d.writes_per_event(), 3.0);
    }
}
