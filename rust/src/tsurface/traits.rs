//! The layered ingestion/readout API for 2D event representations
//! (paper Sec. II-B), split along the two hardware data paths:
//!
//! * [`EventSink`] — the *write* path. Events arrive in stream order,
//!   preferably as sorted batches ([`EventSink::ingest_batch`]): the
//!   batch is what lets a software representation touch shard-local
//!   cells contiguously instead of paying per-event dispatch, mirroring
//!   how the 3DS-ISC plane absorbs a burst of events in place. Simple
//!   representations only implement the per-event [`EventSink::ingest`]
//!   and inherit a correct batch loop.
//! * [`FrameSource`] — the *read* path. [`FrameSource::frame_into`]
//!   renders into a caller-owned [`Grid`], so a serving loop emits
//!   frames with zero steady-state heap allocations; the allocating
//!   [`FrameSource::frame`] wrapper stays for one-shot use.
//! * [`Representation`] — the combined object-safe trait adding the
//!   paper's comparison axes: memory footprint (bits) and memory writes
//!   per event (SITS/TOS need 25–50× writes, which is why they are
//!   hostile to low-energy hardware).
//!
//! Migration from the pre-batch API: `Representation::update(&Event)` is
//! now [`EventSink::ingest`]; bulk callers should hand sorted slices to
//! [`EventSink::ingest_batch`]; `frame(t)` still exists but hot paths
//! should pass a reused buffer to [`FrameSource::frame_into`].

use crate::events::{Event, LabeledEvent, Resolution};
use crate::util::grid::Grid;

/// Batch-first event ingestion (the write path of a representation).
pub trait EventSink {
    /// Ingest one event (stream order).
    fn ingest(&mut self, e: &Event);

    /// Ingest a time-sorted batch. The default loops over [`Self::ingest`]
    /// and is always semantically identical to repeated single-event
    /// ingestion; implementations override it to hoist per-event work
    /// (field loads, plane selection, bounds) out of the inner loop.
    fn ingest_batch(&mut self, events: &[Event]) {
        for e in events {
            self.ingest(e);
        }
    }

    /// Events ingested so far.
    fn events_seen(&self) -> u64;

    /// Total memory write operations performed so far (cells touched).
    fn memory_writes(&self) -> u64;

    /// Memory writes per event — the paper's key hardware-cost metric.
    fn writes_per_event(&self) -> f64 {
        if self.events_seen() == 0 {
            0.0
        } else {
            self.memory_writes() as f64 / self.events_seen() as f64
        }
    }

    /// Start a new accumulation window. Decay-based surfaces carry state
    /// across windows (like the hardware) — default no-op; per-window
    /// accumulators (count/binary images) clear themselves here.
    fn reset_window(&mut self) {}

    /// Sensor geometry this sink covers.
    fn resolution(&self) -> Resolution;
}

/// Feed a sorted labeled stream to a sink in bounded batches: raw events
/// are staged `chunk` at a time into one reused buffer, so bulk callers
/// get the batched inner loop without ever duplicating the full stream.
pub fn ingest_labeled<S: EventSink + ?Sized>(sink: &mut S, events: &[LabeledEvent], chunk: usize) {
    let chunk = chunk.max(1);
    let mut staged: Vec<Event> = Vec::with_capacity(chunk.min(events.len()));
    for part in events.chunks(chunk) {
        staged.clear();
        staged.extend(part.iter().map(|le| le.ev));
        sink.ingest_batch(&staged);
    }
}

/// Allocation-free frame readout (the read path of a representation).
pub trait FrameSource: EventSink {
    /// Render the representation as a [0, 1] frame at query time `t_us`
    /// into `out`, reshaping it to [`EventSink::resolution`] if needed.
    /// Every cell of `out` is overwritten; a warm (right-shaped) buffer
    /// is never reallocated.
    fn frame_into(&self, out: &mut Grid<f64>, t_us: u64);

    /// Allocating convenience wrapper around [`Self::frame_into`].
    fn frame(&self, t_us: u64) -> Grid<f64> {
        let res = self.resolution();
        let mut out = Grid::new(res.width as usize, res.height as usize, 0.0);
        self.frame_into(&mut out, t_us);
        out
    }
}

/// A complete 2D event-stream representation: batch ingestion, zero-copy
/// readout, plus the Sec. II-B resource-accounting axes.
pub trait Representation: FrameSource {
    /// Human-readable name.
    fn name(&self) -> &'static str;

    /// Storage footprint in bits for the whole array.
    fn memory_bits(&self) -> u64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    struct Dummy {
        res: Resolution,
        n: u64,
        batches: u64,
    }
    impl EventSink for Dummy {
        fn ingest(&mut self, _e: &Event) {
            self.n += 1;
        }
        fn ingest_batch(&mut self, events: &[Event]) {
            self.batches += 1;
            for e in events {
                self.ingest(e);
            }
        }
        fn events_seen(&self) -> u64 {
            self.n
        }
        fn memory_writes(&self) -> u64 {
            3 * self.n
        }
        fn resolution(&self) -> Resolution {
            self.res
        }
    }
    impl FrameSource for Dummy {
        fn frame_into(&self, out: &mut Grid<f64>, _t: u64) {
            out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
            out.fill(self.n as f64);
        }
    }
    impl Representation for Dummy {
        fn name(&self) -> &'static str {
            "dummy"
        }
        fn memory_bits(&self) -> u64 {
            8
        }
    }

    fn ev(t: u64) -> Event {
        Event::new(t, 0, 0, Polarity::On)
    }

    #[test]
    fn writes_per_event_ratio() {
        let mut d = Dummy { res: Resolution::new(2, 2), n: 0, batches: 0 };
        assert_eq!(d.writes_per_event(), 0.0);
        d.ingest(&ev(1));
        d.ingest(&ev(2));
        assert_eq!(d.writes_per_event(), 3.0);
    }

    #[test]
    fn batch_ingest_counts_every_event() {
        let mut d = Dummy { res: Resolution::new(2, 2), n: 0, batches: 0 };
        d.ingest_batch(&[ev(1), ev(2), ev(3)]);
        assert_eq!(d.events_seen(), 3);
        assert_eq!(d.batches, 1);
    }

    #[test]
    fn frame_wrapper_matches_frame_into() {
        let mut d = Dummy { res: Resolution::new(3, 2), n: 0, batches: 0 };
        d.ingest(&ev(1));
        let g = d.frame(10);
        let mut buf = Grid::new(1, 1, 0.0);
        d.frame_into(&mut buf, 10);
        assert_eq!(g, buf);
        assert_eq!(g.width(), 3);
        assert_eq!(g.height(), 2);
    }

    #[test]
    fn ingest_labeled_chunks_without_losing_events() {
        let mut d = Dummy { res: Resolution::new(2, 2), n: 0, batches: 0 };
        let les: Vec<LabeledEvent> =
            (0..10).map(|k| LabeledEvent { ev: ev(k), is_signal: true }).collect();
        ingest_labeled(&mut d, &les, 3);
        assert_eq!(d.events_seen(), 10);
        assert_eq!(d.batches, 4); // 3+3+3+1
    }

    #[test]
    fn object_safe_boxed_usage() {
        let mut b: Box<dyn Representation> =
            Box::new(Dummy { res: Resolution::new(2, 2), n: 0, batches: 0 });
        b.ingest_batch(&[ev(1), ev(2)]);
        assert_eq!(b.events_seen(), 2);
        assert_eq!(b.name(), "dummy");
        let f = b.frame(5);
        assert!(f.as_slice().iter().all(|&v| v == 2.0));
    }
}
