//! The ISC-backed time-surface: the [`Representation`] view of the analog
//! array, so the hardware TS drops into every pipeline slot where the
//! ideal/digital surfaces go (classification, reconstruction, denoising
//! comparisons all use this adapter). Frame readout inherits the array's
//! activity-aware O(active) path (see [`crate::isc`] module docs).

use super::traits::{EventSink, FrameSource, Representation};
use crate::events::{Event, Resolution};
use crate::isc::{IscArray, IscConfig};
use crate::util::grid::Grid;

/// Time-surface produced by the simulated ISC analog array.
pub struct IscTs {
    array: IscArray,
}

impl IscTs {
    pub fn new(res: Resolution, cfg: IscConfig) -> Self {
        Self { array: IscArray::new(res, cfg) }
    }

    pub fn with_defaults(res: Resolution) -> Self {
        Self::new(res, IscConfig::default())
    }

    pub fn array(&self) -> &IscArray {
        &self.array
    }

    pub fn array_mut(&mut self) -> &mut IscArray {
        &mut self.array
    }
}

impl EventSink for IscTs {
    fn ingest(&mut self, e: &Event) {
        self.array.write(e);
    }

    fn ingest_batch(&mut self, events: &[Event]) {
        self.array.write_batch(events);
    }

    fn memory_writes(&self) -> u64 {
        self.array.write_count()
    }

    fn events_seen(&self) -> u64 {
        self.array.write_count()
    }

    fn resolution(&self) -> Resolution {
        self.array.resolution()
    }
}

impl FrameSource for IscTs {
    fn frame_into(&self, out: &mut Grid<f64>, t_us: u64) {
        self.array.frame_merged_into(out, t_us);
    }
}

impl Representation for IscTs {
    fn name(&self) -> &'static str {
        "3DS-ISC"
    }

    fn memory_bits(&self) -> u64 {
        // One analog cell per pixel (per polarity plane): the hardware
        // equivalent of a single stored value. We count the effective
        // analog precision (~6 b usable given <2 % CV) per plane.
        let planes = if self.array.config().polarity_sensitive { 2 } else { 1 };
        self.array.resolution().pixels() as u64 * 6 * planes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    #[test]
    fn adapter_tracks_array() {
        let mut ts = IscTs::with_defaults(Resolution::new(8, 8));
        ts.ingest(&Event::new(1_000, 2, 2, Polarity::On));
        assert_eq!(ts.events_seen(), 1);
        assert_eq!(ts.writes_per_event(), 1.0);
        let f = ts.frame(1_000);
        assert!(*f.get(2, 2) > 0.9);
    }

    #[test]
    fn batch_ingest_matches_array_batch_write() {
        let res = Resolution::new(8, 8);
        let events: Vec<Event> =
            (0..30u64).map(|k| Event::new(1 + k * 500, (k % 8) as u16, (k / 8 % 8) as u16,
                                          Polarity::On)).collect();
        let mut ts = IscTs::with_defaults(res);
        ts.ingest_batch(&events);
        let mut arr = IscArray::new(res, IscConfig::default());
        arr.write_batch(&events);
        assert_eq!(ts.frame(20_000), arr.frame_merged(20_000));
        assert_eq!(ts.events_seen(), 30);
    }

    #[test]
    fn memory_far_below_sram_sae() {
        let isc = IscTs::with_defaults(Resolution::QVGA);
        let sae_bits = Resolution::QVGA.pixels() as u64 * 16;
        assert!(isc.memory_bits() < sae_bits);
    }

    #[test]
    fn hardware_ts_close_to_ideal_ts() {
        // The paper's central algorithmic claim: the analog TS ≈ the ideal
        // exponential TS. Compare frames after a short stream.
        use super::super::sae::IdealTs;
        let res = Resolution::new(16, 16);
        let mut hw = IscTs::with_defaults(res);
        // τ chosen to match the analog decay's effective window.
        let mut ideal = IdealTs::new(res, 24_000.0);
        let mut t = 1_000u64;
        for k in 0..64u64 {
            let e = Event::new(t, (k % 16) as u16, ((k / 16) * 3 % 16) as u16, Polarity::On);
            hw.ingest(&e);
            ideal.ingest(&e);
            t += 700;
        }
        let fh = hw.frame(t);
        let fi = ideal.frame(t);
        // Rank agreement: most-recent pixel should be brightest in both.
        let argmax = |g: &Grid<f64>| {
            g.as_slice()
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        assert_eq!(argmax(&fh), argmax(&fi));
        // Values correlated: Pearson r over written pixels > 0.9.
        let (hs, is): (Vec<f64>, Vec<f64>) = fh
            .as_slice()
            .iter()
            .zip(fi.as_slice())
            .filter(|(a, b)| **a > 0.0 || **b > 0.0)
            .map(|(a, b)| (*a, *b))
            .unzip();
        let (_, _, r2) = crate::util::stats::linreg(&hs, &is);
        assert!(r2 > 0.8, "hardware vs ideal TS r² = {r2}");
    }
}
