//! Write-heavy / multi-word representations: SITS [41], TOS [42] and
//! TORE [65].
//!
//! SITS and TOS touch an entire neighbourhood per event (≈25–50 memory
//! writes/event — the paper's Sec. II-B argument for why they are hostile
//! to low-energy hardware). TORE keeps a per-pixel FIFO of the K most
//! recent timestamps per polarity (≥96 b/pixel — the paper's Sec. IV-D
//! area argument: ≥16× the ISC cell).
//!
//! The neighbourhood updates are order-dependent, so these sinks keep the
//! provided per-event batch loop ([`EventSink::ingest_batch`] default) —
//! their write amplification *is* the point being measured.

use super::traits::{EventSink, FrameSource, Representation};
use crate::events::{Event, Resolution};
use crate::util::grid::Grid;

/// Speed-Invariant Time Surface: on each event, neighbours with values
/// above the incoming cell's are decremented and the cell is set to the
/// maximum ordinal (2r+1)².
pub struct Sits {
    res: Resolution,
    r: usize,
    vals: Vec<u16>,
    events: u64,
    writes: u64,
}

impl Sits {
    pub fn new(res: Resolution, r: usize) -> Self {
        assert!(r >= 1);
        Self { res, r, vals: vec![0; res.pixels()], events: 0, writes: 0 }
    }

    pub fn max_val(&self) -> u16 {
        ((2 * self.r + 1) * (2 * self.r + 1)) as u16
    }

    pub fn value(&self, x: u16, y: u16) -> u16 {
        self.vals[self.res.index(x, y)]
    }
}

impl EventSink for Sits {
    fn ingest(&mut self, e: &Event) {
        let (w, h) = (self.res.width as i64, self.res.height as i64);
        let (ex, ey) = (e.x as i64, e.y as i64);
        let center = self.res.index(e.x, e.y);
        let v_center = self.vals[center];
        let r = self.r as i64;
        for dy in -r..=r {
            for dx in -r..=r {
                let (x, y) = (ex + dx, ey + dy);
                if x < 0 || y < 0 || x >= w || y >= h || (dx == 0 && dy == 0) {
                    continue;
                }
                let i = (y * w + x) as usize;
                if self.vals[i] > v_center {
                    self.vals[i] -= 1;
                    self.writes += 1;
                }
            }
        }
        self.vals[center] = self.max_val();
        self.writes += 1;
        self.events += 1;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl FrameSource for Sits {
    fn frame_into(&self, out: &mut Grid<f64>, _t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let m = self.max_val() as f64;
        let s = out.as_mut_slice();
        for (o, &v) in s.iter_mut().zip(&self.vals) {
            *o = v as f64 / m;
        }
    }
}

impl Representation for Sits {
    fn name(&self) -> &'static str {
        "SITS"
    }

    fn memory_bits(&self) -> u64 {
        // Ordinal values up to (2r+1)²: 8 bits suffice for r ≤ 7.
        self.res.pixels() as u64 * 8
    }
}

/// Time Ordinal Surface (luvHarris): event sets its cell to 255; every
/// other cell in the (2r+1)² patch decays by 1 (clamped at 0).
pub struct Tos {
    res: Resolution,
    r: usize,
    vals: Vec<u8>,
    events: u64,
    writes: u64,
}

impl Tos {
    pub fn new(res: Resolution, r: usize) -> Self {
        Self { res, r, vals: vec![0; res.pixels()], events: 0, writes: 0 }
    }

    pub fn value(&self, x: u16, y: u16) -> u8 {
        self.vals[self.res.index(x, y)]
    }
}

impl EventSink for Tos {
    fn ingest(&mut self, e: &Event) {
        let (w, h) = (self.res.width as i64, self.res.height as i64);
        let (ex, ey) = (e.x as i64, e.y as i64);
        let r = self.r as i64;
        for dy in -r..=r {
            for dx in -r..=r {
                let (x, y) = (ex + dx, ey + dy);
                if x < 0 || y < 0 || x >= w || y >= h || (dx == 0 && dy == 0) {
                    continue;
                }
                let i = (y * w + x) as usize;
                if self.vals[i] > 0 {
                    self.vals[i] -= 1;
                    self.writes += 1;
                }
            }
        }
        let c = self.res.index(e.x, e.y);
        self.vals[c] = 255;
        self.writes += 1;
        self.events += 1;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl FrameSource for Tos {
    fn frame_into(&self, out: &mut Grid<f64>, _t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let s = out.as_mut_slice();
        for (o, &v) in s.iter_mut().zip(&self.vals) {
            *o = v as f64 / 255.0;
        }
    }
}

impl Representation for Tos {
    fn name(&self) -> &'static str {
        "TOS"
    }

    fn memory_bits(&self) -> u64 {
        self.res.pixels() as u64 * 8
    }
}

/// Time-Ordered Recent Events: per-pixel, per-polarity FIFO of the K most
/// recent event times. Frame value maps each FIFO entry's age through a
/// clipped log kernel and averages (TORE volume collapsed to one channel).
pub struct Tore {
    res: Resolution,
    k: usize,
    /// FIFOs: [pixel][polarity] → ring of timestamps (µs, 0 = empty).
    fifo: Vec<[Vec<u64>; 2]>,
    /// Log-kernel clip range (µs).
    pub t_min_us: f64,
    pub t_max_us: f64,
    events: u64,
    writes: u64,
}

impl Tore {
    pub fn new(res: Resolution, k: usize, t_min_us: f64, t_max_us: f64) -> Self {
        assert!(k >= 1 && t_max_us > t_min_us && t_min_us > 0.0);
        Self {
            res,
            k,
            fifo: vec![[Vec::new(), Vec::new()]; res.pixels()],
            t_min_us,
            t_max_us,
            events: 0,
            writes: 0,
        }
    }

    /// Collapsed TORE value at a pixel: mean over both polarities' FIFOs of
    /// 1 − clamp(log(Δt/t_min)/log(t_max/t_min)).
    pub fn value(&self, x: u16, y: u16, t_us: u64) -> f64 {
        self.cell_value(&self.fifo[self.res.index(x, y)], t_us)
    }

    fn cell_value(&self, cell: &[Vec<u64>; 2], t_us: u64) -> f64 {
        let denom = (self.t_max_us / self.t_min_us).ln();
        let mut sum = 0.0;
        let mut n = 0usize;
        for plane in cell {
            for &tw in plane {
                if tw == 0 || t_us < tw {
                    continue;
                }
                let dt = ((t_us - tw) as f64).max(self.t_min_us);
                let v = 1.0 - ((dt / self.t_min_us).ln() / denom).clamp(0.0, 1.0);
                sum += v;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            // Normalize by total FIFO capacity so the value stays in [0, 1].
            sum / (2.0 * self.k as f64)
        }
    }
}

impl EventSink for Tore {
    fn ingest(&mut self, e: &Event) {
        let cell = &mut self.fifo[self.res.index(e.x, e.y)];
        let q = &mut cell[e.p.index()];
        q.push(e.t.max(1));
        if q.len() > self.k {
            q.remove(0);
        }
        self.events += 1;
        self.writes += 1;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl FrameSource for Tore {
    fn frame_into(&self, out: &mut Grid<f64>, t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let s = out.as_mut_slice();
        for (o, cell) in s.iter_mut().zip(&self.fifo) {
            *o = self.cell_value(cell, t_us);
        }
    }
}

impl Representation for Tore {
    fn name(&self) -> &'static str {
        "TORE"
    }

    fn memory_bits(&self) -> u64 {
        // K stamps × 2 polarities × 32-bit floats minimum (paper: ≥96 b).
        self.res.pixels() as u64 * self.k as u64 * 2 * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    fn ev(t: u64, x: u16, y: u16) -> Event {
        Event::new(t, x, y, Polarity::On)
    }

    #[test]
    fn sits_write_amplification() {
        // Paper Sec. II-B: SITS needs ~25–50× the writes of SAE. With r=3
        // on a busy patch the per-event write count approaches (2r+1)²=49.
        let mut s = Sits::new(Resolution::new(32, 32), 3);
        // Saturate a neighbourhood so most cells hold high ordinals.
        for k in 0..2_000u64 {
            s.ingest(&ev(k, (10 + k % 8) as u16, (10 + (k / 8) % 8) as u16));
        }
        let wpe = s.writes_per_event();
        assert!(wpe > 10.0, "SITS writes/event {wpe}");
        assert!(wpe <= 49.0);
    }

    #[test]
    fn tos_write_amplification() {
        let mut t = Tos::new(Resolution::new(32, 32), 3);
        for k in 0..2_000u64 {
            t.ingest(&ev(k, (10 + k % 8) as u16, (10 + (k / 8) % 8) as u16));
        }
        assert!(t.writes_per_event() > 10.0);
    }

    #[test]
    fn sae_class_single_write() {
        let mut s = super::super::sae::Sae::new(Resolution::new(32, 32));
        for k in 0..100u64 {
            s.ingest(&ev(k, 5, 5));
        }
        assert_eq!(s.writes_per_event(), 1.0);
    }

    #[test]
    fn sits_center_maximal_after_event() {
        let mut s = Sits::new(Resolution::new(8, 8), 2);
        s.ingest(&ev(1, 4, 4));
        assert_eq!(s.value(4, 4), s.max_val());
    }

    #[test]
    fn sits_batch_matches_sequential() {
        // Order-dependent neighbourhood updates: the provided batch loop
        // must reproduce event-at-a-time semantics exactly.
        let evs: Vec<Event> =
            (0..300u64).map(|k| ev(k, (3 + k % 9) as u16, (3 + (k / 9) % 9) as u16)).collect();
        let mut a = Sits::new(Resolution::new(16, 16), 2);
        let mut b = Sits::new(Resolution::new(16, 16), 2);
        for e in &evs {
            a.ingest(e);
        }
        b.ingest_batch(&evs);
        assert_eq!(a.frame(300), b.frame(300));
        assert_eq!(a.memory_writes(), b.memory_writes());
    }

    #[test]
    fn tore_fifo_depth_bounded() {
        let mut t = Tore::new(Resolution::new(4, 4), 3, 100.0, 1e6);
        for k in 0..10u64 {
            t.ingest(&ev(1 + k * 1_000, 1, 1));
        }
        // Value bounded and newer events dominate.
        let v_now = t.value(1, 1, 9_001);
        let v_later = t.value(1, 1, 2_000_000);
        assert!(v_now > v_later);
        assert!((0.0..=1.0).contains(&v_now));
    }

    #[test]
    fn tore_memory_exceeds_isc_16x() {
        // Paper Sec. IV-D: TORE ≥96 b/pixel vs the single analog cell.
        let t = Tore::new(Resolution::QVGA, 3, 100.0, 1e6);
        let bits_per_pixel = t.memory_bits() / Resolution::QVGA.pixels() as u64;
        assert!(bits_per_pixel >= 96, "TORE bits/pixel {bits_per_pixel}");
    }

    #[test]
    fn tore_polarity_separated() {
        let mut t = Tore::new(Resolution::new(2, 2), 2, 100.0, 1e6);
        t.ingest(&Event::new(1_000, 0, 0, Polarity::On));
        t.ingest(&Event::new(2_000, 0, 0, Polarity::Off));
        assert_eq!(t.fifo[0][Polarity::On.index()].len(), 1);
        assert_eq!(t.fifo[0][Polarity::Off.index()].len(), 1);
    }
}
