//! Write-heavy / multi-word representations: SITS [41], TOS [42] and
//! TORE [65].
//!
//! SITS and TOS touch an entire neighbourhood per event (≈25–50 memory
//! writes/event — the paper's Sec. II-B argument for why they are hostile
//! to low-energy hardware). Their neighbourhood updates run over
//! [`Grid::row_mut`] slices (one contiguous slice per patch row, no
//! per-element 2D index math). TORE keeps a per-pixel FIFO of the K most
//! recent timestamps per polarity (≥96 b/pixel — the paper's Sec. IV-D
//! area argument: ≥16× the ISC cell); its clipped-log kernel is read
//! through the shared quantized [`DecayLut`], so frame readout performs
//! no `ln()` per FIFO entry.
//!
//! The neighbourhood updates are order-dependent, so these sinks keep the
//! provided per-event batch loop ([`EventSink::ingest_batch`] default) —
//! their write amplification *is* the point being measured.

use super::traits::{EventSink, FrameSource, Representation};
use crate::events::{Event, Resolution};
use crate::util::decay::{DecayLut, MAX_BINS};
use crate::util::grid::{patch_bounds, Grid};
use crate::util::parallel::{auto_chunks, balanced_row_ranges, for_each_row_chunk};

/// Speed-Invariant Time Surface: on each event, neighbours with values
/// above the incoming cell's are decremented and the cell is set to the
/// maximum ordinal (2r+1)².
pub struct Sits {
    res: Resolution,
    r: usize,
    vals: Grid<u16>,
    events: u64,
    writes: u64,
}

impl Sits {
    pub fn new(res: Resolution, r: usize) -> Self {
        assert!(r >= 1);
        Self {
            res,
            r,
            vals: Grid::new(res.width as usize, res.height as usize, 0),
            events: 0,
            writes: 0,
        }
    }

    pub fn max_val(&self) -> u16 {
        ((2 * self.r + 1) * (2 * self.r + 1)) as u16
    }

    pub fn value(&self, x: u16, y: u16) -> u16 {
        *self.vals.get(x as usize, y as usize)
    }
}

impl EventSink for Sits {
    fn ingest(&mut self, e: &Event) {
        let (cx, cy) = (e.x as usize, e.y as usize);
        let (x0, x1) = patch_bounds(cx, self.r, self.res.width as usize);
        let (y0, y1) = patch_bounds(cy, self.r, self.res.height as usize);
        let v_center = *self.vals.get(cx, cy);
        for y in y0..=y1 {
            // Row-sliced decrement; the center cell never satisfies
            // `> v_center` against itself, so no skip is needed.
            for v in &mut self.vals.row_mut(y)[x0..=x1] {
                if *v > v_center {
                    *v -= 1;
                    self.writes += 1;
                }
            }
        }
        let m = self.max_val();
        self.vals.set(cx, cy, m);
        self.writes += 1;
        self.events += 1;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl FrameSource for Sits {
    fn frame_into(&self, out: &mut Grid<f64>, _t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let m = self.max_val() as f64;
        let s = out.as_mut_slice();
        for (o, &v) in s.iter_mut().zip(self.vals.as_slice()) {
            *o = v as f64 / m;
        }
    }
}

impl Representation for Sits {
    fn name(&self) -> &'static str {
        "SITS"
    }

    fn memory_bits(&self) -> u64 {
        // Ordinal values up to (2r+1)²: 8 bits suffice for r ≤ 7.
        self.res.pixels() as u64 * 8
    }
}

/// Time Ordinal Surface (luvHarris): event sets its cell to 255; every
/// other cell in the (2r+1)² patch decays by 1 (clamped at 0).
pub struct Tos {
    res: Resolution,
    r: usize,
    vals: Grid<u8>,
    events: u64,
    writes: u64,
}

impl Tos {
    pub fn new(res: Resolution, r: usize) -> Self {
        Self {
            res,
            r,
            vals: Grid::new(res.width as usize, res.height as usize, 0),
            events: 0,
            writes: 0,
        }
    }

    pub fn value(&self, x: u16, y: u16) -> u8 {
        *self.vals.get(x as usize, y as usize)
    }
}

impl EventSink for Tos {
    fn ingest(&mut self, e: &Event) {
        let (cx, cy) = (e.x as usize, e.y as usize);
        let (x0, x1) = patch_bounds(cx, self.r, self.res.width as usize);
        let (y0, y1) = patch_bounds(cy, self.r, self.res.height as usize);
        let mut writes = 0u64;
        let mut dec = |cells: &mut [u8]| {
            for v in cells {
                if *v > 0 {
                    *v -= 1;
                    writes += 1;
                }
            }
        };
        for y in y0..=y1 {
            let row = &mut self.vals.row_mut(y)[x0..=x1];
            if y == cy {
                // Split around the center: the event's own cell is set,
                // not decayed.
                let c = cx - x0;
                let (left, rest) = row.split_at_mut(c);
                dec(left);
                dec(&mut rest[1..]);
            } else {
                dec(row);
            }
        }
        self.writes += writes;
        self.vals.set(cx, cy, 255);
        self.writes += 1;
        self.events += 1;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl FrameSource for Tos {
    fn frame_into(&self, out: &mut Grid<f64>, _t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let s = out.as_mut_slice();
        for (o, &v) in s.iter_mut().zip(self.vals.as_slice()) {
            *o = v as f64 / 255.0;
        }
    }
}

impl Representation for Tos {
    fn name(&self) -> &'static str {
        "TOS"
    }

    fn memory_bits(&self) -> u64 {
        self.res.pixels() as u64 * 8
    }
}

/// Time-Ordered Recent Events: per-pixel, per-polarity FIFO of the K most
/// recent event times. Frame value maps each FIFO entry's age through a
/// clipped log kernel and averages (TORE volume collapsed to one channel).
///
/// The kernel `1 − clamp(ln(Δt/t_min)/ln(t_max/t_min))` is precomputed
/// into a [`DecayLut`] at construction: readout is one table load per
/// FIFO entry, with the step tied to t_min so the per-entry error stays
/// ≤ `ln(1 + step/t_min)/ln(t_max/t_min)`, and ages past the table
/// horizon (≥ t_max) read exactly 0 — which is also what the clamp
/// yields there.
pub struct Tore {
    res: Resolution,
    k: usize,
    /// FIFOs: [pixel][polarity] → ring of timestamps (µs, 0 = empty).
    fifo: Vec<[Vec<u64>; 2]>,
    /// Log-kernel clip range (µs).
    pub t_min_us: f64,
    pub t_max_us: f64,
    lut: DecayLut,
    events: u64,
    writes: u64,
}

impl Tore {
    pub fn new(res: Resolution, k: usize, t_min_us: f64, t_max_us: f64) -> Self {
        assert!(k >= 1 && t_max_us > t_min_us && t_min_us > 0.0);
        // The log kernel is steepest at t_min, so the LUT step tracks
        // t_min/8: per-entry error ≤ ln(1 + step/t_min)/ln(t_max/t_min)
        // (≈1.3 % at the 100 µs/1 s defaults). The table is capped at
        // 8·MAX_BINS entries — the step widens past that, and the
        // horizon always covers t_max (no early cliff to 0).
        let step = ((t_min_us / 8.0).ceil() as u64)
            .max((t_max_us / (8 * MAX_BINS) as f64).ceil() as u64)
            .max(1);
        let bins = ((t_max_us / step as f64).ceil() as usize).max(64);
        let denom = (t_max_us / t_min_us).ln();
        let lut = DecayLut::build(1, bins, step, |_, dt_us| {
            let dt = (dt_us as f64).max(t_min_us);
            1.0 - ((dt / t_min_us).ln() / denom).clamp(0.0, 1.0)
        });
        Self {
            res,
            k,
            fifo: vec![[Vec::new(), Vec::new()]; res.pixels()],
            t_min_us,
            t_max_us,
            lut,
            events: 0,
            writes: 0,
        }
    }

    /// Collapsed TORE value at a pixel: mean over both polarities' FIFOs of
    /// 1 − clamp(log(Δt/t_min)/log(t_max/t_min)), via the quantized LUT.
    pub fn value(&self, x: u16, y: u16, t_us: u64) -> f64 {
        self.cell_value(&self.fifo[self.res.index(x, y)], t_us)
    }

    fn cell_value(&self, cell: &[Vec<u64>; 2], t_us: u64) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for plane in cell {
            for &tw in plane {
                if tw == 0 || t_us < tw {
                    continue;
                }
                sum += self.lut.eval(0, t_us - tw);
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            // Normalize by total FIFO capacity so the value stays in [0, 1].
            sum / (2.0 * self.k as f64)
        }
    }
}

impl EventSink for Tore {
    fn ingest(&mut self, e: &Event) {
        let cell = &mut self.fifo[self.res.index(e.x, e.y)];
        let q = &mut cell[e.p.index()];
        q.push(e.t.max(1));
        if q.len() > self.k {
            q.remove(0);
        }
        self.events += 1;
        self.writes += 1;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl Tore {
    /// [`FrameSource::frame_into`] with an explicit row-chunk count:
    /// rows split across scoped threads, weight-balanced by per-row
    /// FIFO occupancy — bit-for-bit identical for every chunk count
    /// (each cell's reduction is independent).
    pub fn frame_into_chunks(&self, out: &mut Grid<f64>, t_us: u64, chunks: usize) {
        let (w, h) = (self.res.width as usize, self.res.height as usize);
        out.ensure_shape(w, h, 0.0);
        let chunks = chunks.clamp(1, h);
        let ranges = if chunks == 1 {
            vec![0..h]
        } else {
            let weights: Vec<usize> = (0..h)
                .map(|y| {
                    1 + self.fifo[y * w..(y + 1) * w]
                        .iter()
                        .map(|c| c[0].len() + c[1].len())
                        .sum::<usize>()
                })
                .collect();
            balanced_row_ranges(&weights, chunks)
        };
        for_each_row_chunk(out, &ranges, |range, slab| {
            for (o, cell) in slab.iter_mut().zip(&self.fifo[range.start * w..range.end * w]) {
                *o = self.cell_value(cell, t_us);
            }
        });
    }
}

impl FrameSource for Tore {
    /// Per-cell FIFO reduction through the clipped-log LUT. The walk is
    /// the costliest per pixel of any representation here (up to 2K LUT
    /// reads per cell), so large frames split the rows across scoped
    /// threads (see [`Tore::frame_into_chunks`]).
    fn frame_into(&self, out: &mut Grid<f64>, t_us: u64) {
        self.frame_into_chunks(out, t_us, auto_chunks(self.res.pixels()));
    }
}

impl Representation for Tore {
    fn name(&self) -> &'static str {
        "TORE"
    }

    fn memory_bits(&self) -> u64 {
        // K stamps × 2 polarities × 32-bit floats minimum (paper: ≥96 b).
        self.res.pixels() as u64 * self.k as u64 * 2 * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    fn ev(t: u64, x: u16, y: u16) -> Event {
        Event::new(t, x, y, Polarity::On)
    }

    #[test]
    fn sits_write_amplification() {
        // Paper Sec. II-B: SITS needs ~25–50× the writes of SAE. With r=3
        // on a busy patch the per-event write count approaches (2r+1)²=49.
        let mut s = Sits::new(Resolution::new(32, 32), 3);
        // Saturate a neighbourhood so most cells hold high ordinals.
        for k in 0..2_000u64 {
            s.ingest(&ev(k, (10 + k % 8) as u16, (10 + (k / 8) % 8) as u16));
        }
        let wpe = s.writes_per_event();
        assert!(wpe > 10.0, "SITS writes/event {wpe}");
        assert!(wpe <= 49.0);
    }

    #[test]
    fn tos_write_amplification() {
        let mut t = Tos::new(Resolution::new(32, 32), 3);
        for k in 0..2_000u64 {
            t.ingest(&ev(k, (10 + k % 8) as u16, (10 + (k / 8) % 8) as u16));
        }
        assert!(t.writes_per_event() > 10.0);
    }

    #[test]
    fn sae_class_single_write() {
        let mut s = super::super::sae::Sae::new(Resolution::new(32, 32));
        for k in 0..100u64 {
            s.ingest(&ev(k, 5, 5));
        }
        assert_eq!(s.writes_per_event(), 1.0);
    }

    #[test]
    fn sits_center_maximal_after_event() {
        let mut s = Sits::new(Resolution::new(8, 8), 2);
        s.ingest(&ev(1, 4, 4));
        assert_eq!(s.value(4, 4), s.max_val());
    }

    #[test]
    fn sits_batch_matches_sequential() {
        // Order-dependent neighbourhood updates: the provided batch loop
        // must reproduce event-at-a-time semantics exactly.
        let evs: Vec<Event> =
            (0..300u64).map(|k| ev(k, (3 + k % 9) as u16, (3 + (k / 9) % 9) as u16)).collect();
        let mut a = Sits::new(Resolution::new(16, 16), 2);
        let mut b = Sits::new(Resolution::new(16, 16), 2);
        for e in &evs {
            a.ingest(e);
        }
        b.ingest_batch(&evs);
        assert_eq!(a.frame(300), b.frame(300));
        assert_eq!(a.memory_writes(), b.memory_writes());
    }

    #[test]
    fn tos_corner_event_clamps_patch() {
        // Border events must decay only the in-bounds part of the patch
        // and never touch the center via the decay pass.
        let mut t = Tos::new(Resolution::new(8, 8), 3);
        t.ingest(&ev(1, 0, 0));
        assert_eq!(t.value(0, 0), 255);
        t.ingest(&ev(2, 1, 1));
        assert_eq!(t.value(1, 1), 255);
        assert_eq!(t.value(0, 0), 254); // decayed once by the neighbour
        assert_eq!(t.memory_writes(), 3); // 2 sets + 1 decrement
    }

    #[test]
    fn tore_fifo_depth_bounded() {
        let mut t = Tore::new(Resolution::new(4, 4), 3, 100.0, 1e6);
        for k in 0..10u64 {
            t.ingest(&ev(1 + k * 1_000, 1, 1));
        }
        // Value bounded and newer events dominate.
        let v_now = t.value(1, 1, 9_001);
        let v_later = t.value(1, 1, 2_000_000);
        assert!(v_now > v_later);
        assert!((0.0..=1.0).contains(&v_now));
    }

    #[test]
    fn tore_lut_tracks_exact_log_kernel() {
        let t = Tore::new(Resolution::new(2, 2), 1, 100.0, 1e6);
        let denom = (t.t_max_us / t.t_min_us).ln();
        let step = t.lut.step_us();
        let kernel =
            |dt: f64| 1.0 - ((dt.max(t.t_min_us) / t.t_min_us).ln() / denom).clamp(0.0, 1.0);
        // The step tracks t_min (≤ t_min/8 rounded up), keeping the
        // kernel's steep region finely sampled.
        assert!(step as f64 <= t.t_min_us / 8.0 + 1.0, "step={step}");
        // Bin edges hold the closed form up to f32 storage rounding.
        for bin in [0u64, 1, 7, 800, 5_000] {
            let dt = bin * step;
            assert!((t.lut.eval(0, dt) - kernel(dt as f64)).abs() < 1e-6, "dt={dt}");
        }
        // Between edges the floor-binned error stays within the
        // documented ln(1 + step/t_min)/ln(t_max/t_min) bound.
        let bound = (1.0 + step as f64 / t.t_min_us).ln() / denom + 1e-6;
        for dt in [109u64, 149, 433, 25_037, 999_999] {
            assert!((t.lut.eval(0, dt) - kernel(dt as f64)).abs() <= bound, "dt={dt}");
        }
        // Far past t_max the LUT horizon reads 0, matching the clamp.
        assert_eq!(t.lut.eval(0, 5_000_000), 0.0);
    }

    #[test]
    fn tore_chunked_frames_identical_for_any_chunk_count() {
        let mut t = Tore::new(Resolution::new(9, 7), 3, 100.0, 1e6);
        for k in 0..200u64 {
            t.ingest(&ev(1 + k * 700, (k % 9) as u16, ((k * 3) % 7) as u16));
        }
        let at = 200 * 700 + 5_000;
        let mut serial = crate::util::grid::Grid::new(1, 1, 0.0);
        let mut chunked = crate::util::grid::Grid::new(1, 1, 0.0);
        t.frame_into_chunks(&mut serial, at, 1);
        // 2, 8 chunks and more chunks than rows (7 rows).
        for chunks in [2usize, 8, 64] {
            t.frame_into_chunks(&mut chunked, at, chunks);
            assert_eq!(serial, chunked, "chunks={chunks}");
        }
    }

    #[test]
    fn tore_memory_exceeds_isc_16x() {
        // Paper Sec. IV-D: TORE ≥96 b/pixel vs the single analog cell.
        let t = Tore::new(Resolution::QVGA, 3, 100.0, 1e6);
        let bits_per_pixel = t.memory_bits() / Resolution::QVGA.pixels() as u64;
        assert!(bits_per_pixel >= 96, "TORE bits/pixel {bits_per_pixel}");
    }

    #[test]
    fn tore_polarity_separated() {
        let mut t = Tore::new(Resolution::new(2, 2), 2, 100.0, 1e6);
        t.ingest(&Event::new(1_000, 0, 0, Polarity::On));
        t.ingest(&Event::new(2_000, 0, 0, Polarity::Off));
        assert_eq!(t.fifo[0][Polarity::On.index()].len(), 1);
        assert_eq!(t.fifo[0][Polarity::Off.index()].len(), 1);
    }
}
