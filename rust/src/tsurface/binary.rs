//! Count-based representations (paper Sec. II-B): event-count images and
//! the event-based binary image (EBBI). Cheap to store but they discard
//! the fine temporal structure the TS keeps.

use super::traits::{EventSink, FrameSource, Representation};
use crate::events::{Event, Resolution};
use crate::util::grid::Grid;

/// Event-count image: per-pixel saturating n_C-bit counter over the
/// current frame window (reset externally per frame).
pub struct EventCount {
    res: Resolution,
    bits: u32,
    counts: Vec<u16>,
    events: u64,
    writes: u64,
}

impl EventCount {
    pub fn new(res: Resolution, bits: u32) -> Self {
        assert!((1..=16).contains(&bits));
        Self { res, bits, counts: vec![0; res.pixels()], events: 0, writes: 0 }
    }

    pub fn max_count(&self) -> u16 {
        ((1u32 << self.bits) - 1) as u16
    }

    /// Start a new frame window.
    pub fn reset_window(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
    }

    pub fn count(&self, x: u16, y: u16) -> u16 {
        self.counts[self.res.index(x, y)]
    }
}

impl EventSink for EventCount {
    fn ingest(&mut self, e: &Event) {
        let i = self.res.index(e.x, e.y);
        if self.counts[i] < self.max_count() {
            self.counts[i] += 1;
            self.writes += 1;
        }
        self.events += 1;
    }

    fn ingest_batch(&mut self, events: &[Event]) {
        let max = self.max_count();
        for e in events {
            let i = self.res.index(e.x, e.y);
            if self.counts[i] < max {
                self.counts[i] += 1;
                self.writes += 1;
            }
        }
        self.events += events.len() as u64;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn reset_window(&mut self) {
        EventCount::reset_window(self);
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl FrameSource for EventCount {
    fn frame_into(&self, out: &mut Grid<f64>, _t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let m = self.max_count() as f64;
        let s = out.as_mut_slice();
        for (o, &c) in s.iter_mut().zip(&self.counts) {
            *o = c as f64 / m;
        }
    }
}

impl Representation for EventCount {
    fn name(&self) -> &'static str {
        "event-count"
    }

    fn memory_bits(&self) -> u64 {
        self.res.pixels() as u64 * self.bits as u64
    }
}

/// Event-based binary image: 1 bit per pixel per window [34], [35].
pub struct Ebbi {
    res: Resolution,
    bits: Vec<bool>,
    events: u64,
    writes: u64,
}

impl Ebbi {
    pub fn new(res: Resolution) -> Self {
        Self { res, bits: vec![false; res.pixels()], events: 0, writes: 0 }
    }

    pub fn reset_window(&mut self) {
        self.bits.iter_mut().for_each(|b| *b = false);
    }

    pub fn get(&self, x: u16, y: u16) -> bool {
        self.bits[self.res.index(x, y)]
    }
}

impl EventSink for Ebbi {
    fn ingest(&mut self, e: &Event) {
        let i = self.res.index(e.x, e.y);
        if !self.bits[i] {
            self.bits[i] = true;
            self.writes += 1;
        }
        self.events += 1;
    }

    fn ingest_batch(&mut self, events: &[Event]) {
        for e in events {
            let i = self.res.index(e.x, e.y);
            if !self.bits[i] {
                self.bits[i] = true;
                self.writes += 1;
            }
        }
        self.events += events.len() as u64;
    }

    fn memory_writes(&self) -> u64 {
        self.writes
    }

    fn events_seen(&self) -> u64 {
        self.events
    }

    fn reset_window(&mut self) {
        Ebbi::reset_window(self);
    }

    fn resolution(&self) -> Resolution {
        self.res
    }
}

impl FrameSource for Ebbi {
    fn frame_into(&self, out: &mut Grid<f64>, _t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let s = out.as_mut_slice();
        for (o, &b) in s.iter_mut().zip(&self.bits) {
            *o = if b { 1.0 } else { 0.0 };
        }
    }
}

impl Representation for Ebbi {
    fn name(&self) -> &'static str {
        "EBBI"
    }

    fn memory_bits(&self) -> u64 {
        self.res.pixels() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Polarity;

    fn ev(t: u64, x: u16, y: u16) -> Event {
        Event::new(t, x, y, Polarity::On)
    }

    #[test]
    fn count_saturates() {
        let mut c = EventCount::new(Resolution::new(2, 2), 2);
        for k in 0..10 {
            c.ingest(&ev(k, 0, 0));
        }
        assert_eq!(c.count(0, 0), 3); // 2-bit max
        assert_eq!(c.events_seen(), 10);
        assert_eq!(c.memory_writes(), 3); // saturated writes skipped
    }

    #[test]
    fn count_batch_preserves_saturation_accounting() {
        let evs: Vec<Event> = (0..10).map(|k| ev(k, 0, 0)).collect();
        let mut c = EventCount::new(Resolution::new(2, 2), 2);
        c.ingest_batch(&evs);
        assert_eq!(c.count(0, 0), 3);
        assert_eq!(c.events_seen(), 10);
        assert_eq!(c.memory_writes(), 3);
    }

    #[test]
    fn ebbi_single_write_per_pixel() {
        let mut b = Ebbi::new(Resolution::new(2, 2));
        for k in 0..5 {
            b.ingest(&ev(k, 1, 1));
        }
        assert!(b.get(1, 1));
        assert_eq!(b.memory_writes(), 1);
        assert!(b.writes_per_event() < 1.0);
    }

    #[test]
    fn memory_ordering_matches_paper() {
        // Sec. II-B: EBBI (H·W) < count (H·W·n_C) < SAE (H·W·n_T).
        let res = Resolution::QVGA;
        let e = Ebbi::new(res);
        let c = EventCount::new(res, 4);
        let s = super::super::sae::Sae::new(res);
        assert!(e.memory_bits() < c.memory_bits());
        assert!(c.memory_bits() < s.memory_bits());
    }

    #[test]
    fn reset_window_clears() {
        let mut c = EventCount::new(Resolution::new(2, 2), 4);
        c.ingest(&ev(1, 0, 0));
        c.reset_window();
        assert_eq!(c.count(0, 0), 0);
        let mut b = Ebbi::new(Resolution::new(2, 2));
        b.ingest(&ev(1, 0, 0));
        b.reset_window();
        assert!(!b.get(0, 0));
    }
}
