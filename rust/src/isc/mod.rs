//! The in-sensor-computing analog array simulator: the software twin of the
//! paper's 3D-stacked 6T-1C eDRAM plane, driven by the Monte-Carlo fitted
//! cell bank from [`crate::circuit`].
//!
//! ## Per-path complexity (activity-aware readout PR 2, parallel readout PR 3)
//!
//! A = cells written within the bank-derived memory horizon (the age at
//! which the slowest cell decays below 1 % of V_dd, ≈102 ms nominal),
//! H·W = resolution, r = STCF patch radius, P = row chunks (auto:
//! `available_parallelism`, gated to 1 below 32 k pixels), D = rows
//! written since the last snapshot.
//!
//! | Path | Before | After | Memory |
//! |---|---|---|---|
//! | event write (`write`/`write_batch`) | O(1) | O(1) amortized (mark + lazy expiry) | O(H·W) stamps + params per plane, counted by [`IscArray::approx_bytes`] |
//! | frame readout (`frame_into`/`frame_merged_into`) | O(H·W) LUT scan | zero-fill + O(A) sorted-run LUT gathers, O(A/P) wall-clock | active lists O(A); recency bitmask +H·W/8 bits per plane when enabled |
//! | dense fallback (activity > α = 20 %) | n/a | O(H·W / P) contiguous row scans (beats the list walk past α) | no extra state |
//! | partial re-render (`frame_merged_rows_into`) | full frame | O(D·W) — the router's dirty-band snapshot unit | band buffers recycled by the caller |
//! | STCF support query (`count_recent_in_row`) | (2r+1)² indexed reads | 2r+1 row slices, integer-age test | dense plane; the O(capacity) alternative is [`crate::denoise::StcfBackend::Cache`] |
//! | STCF support query, bitmask tier (`recency_plane`) | 2r+1 row slices | 2r+1 masked `u64` word loads + exact confirms of set-bit runs only (see [`crate::denoise`]) | H·W/8 bits × 4 epoch buckets |
//! | exact point read (`read`/`compare`) | closed form | unchanged (reference) | no extra state |
//!
//! A band array that sits idle past the memory horizon is **fully
//! expired** ([`IscArray::fully_expired_at`]): it reads zero forever
//! absent new writes, and — with the position-stable assignment — a
//! freshly constructed array is bit-for-bit indistinguishable from it
//! for all future causal reads. The coordinator/serve layers use this
//! to demote cold bands to an unmaterialized state (lazy band
//! materialization), making per-session resident bytes
//! activity-proportional.
//!
//! Chunked rendering is bit-for-bit identical for every chunk count
//! (each output row is a pure function of immutable plane state —
//! mirroring the tiled analog readout, where every pixel is sampled
//! concurrently by construction). The list/dense mode switch is decided
//! per plane from total activity, never per chunk, so it cannot differ
//! between the serial and parallel renders of one frame.
//!
//! This is the software mirror of the paper's passive-decay energy
//! model: idle cells cost nothing at write time *and* readout time.
//!
//! Per-pixel mismatch parameters are assigned **position-stably**: every
//! cell hashes its global (plane, x, y) position into the shared fitted
//! bank ([`array::param_index_at`]), and band-local arrays anchor
//! themselves with [`IscConfig::origin_y`] — so any band partition of
//! the sensor (router write shards, denoise shards, serve sessions)
//! carries exactly the full-sensor mismatch map over its rows and
//! sharded results equal serial results bit for bit.

pub mod array;

pub use array::{param_index_at, IscArray, IscConfig};
