//! The in-sensor-computing analog array simulator: the software twin of the
//! paper's 3D-stacked 6T-1C eDRAM plane, driven by the Monte-Carlo fitted
//! cell bank from [`crate::circuit`].
//!
//! ## Per-path complexity (activity-aware readout, PR 2)
//!
//! A = cells written within the bank-derived memory horizon (the age at
//! which the slowest cell decays below 1 % of V_dd, ≈102 ms nominal),
//! H·W = resolution, r = STCF patch radius.
//!
//! | Path | Before | After |
//! |---|---|---|
//! | event write (`write`/`write_batch`) | O(1) | O(1) amortized (mark + lazy expiry) |
//! | frame readout (`frame_into`/`frame_merged_into`) | O(H·W) LUT scan | zero-fill + O(A) LUT reads |
//! | STCF support query (`count_recent_in_row`) | (2r+1)² indexed reads | 2r+1 row slices, integer-age test |
//! | exact point read (`read`/`compare`) | closed form | unchanged (reference) |
//!
//! This is the software mirror of the paper's passive-decay energy
//! model: idle cells cost nothing at write time *and* readout time.

pub mod array;

pub use array::{IscArray, IscConfig};
