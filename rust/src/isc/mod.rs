//! The in-sensor-computing analog array simulator: the software twin of the
//! paper's 3D-stacked 6T-1C eDRAM plane, driven by the Monte-Carlo fitted
//! cell bank from [`crate::circuit`].

pub mod array;

pub use array::{IscArray, IscConfig};
