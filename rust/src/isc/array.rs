//! The ISC analog array simulator — the paper's core hardware contribution
//! as a software twin.
//!
//! Each pixel owns a 6T-1C eDRAM cell (per polarity in polarity mode). An
//! event writes V_reset = V_dd through the Cu-Cu bond; the stored voltage
//! then decays along that cell's double-exponential (sampled from the
//! Monte-Carlo fitted bank, Sec. IV-C). Because the decay is a *passive*
//! physical process, the simulator never touches idle pixels: state is
//! (last-write time, per-pixel decay parameters) plus per-row active-pixel
//! lists and recency bitmask words, and V_mem is evaluated lazily at
//! read/compare time — O(1) per event, O(patch words + confirms) per
//! STCF query, O(active) per frame readout. This
//! mirrors the actual hardware's energy profile and is also what makes
//! the software hot path fast.
//!
//! Readout goes through the shared quantized decay LUT
//! ([`crate::util::decay::DecayLut`], 50 µs bins; the horizon is derived
//! from the decay bank as the age at which the slowest cell falls below
//! 1 % of V_dd — ≈102 ms for the 20 fF nominal cell, longer for larger
//! C_mem); cells older than the horizon read exactly 0 and are lazily
//! dropped from the active lists on the write path
//! ([`crate::util::active`]).

use crate::circuit::montecarlo::{FittedBank, MismatchParams};
use crate::circuit::params::VDD;
use crate::events::{Event, Polarity, Resolution};
use crate::util::active::{for_each_sorted_run, ActiveSet, DENSE_FALLBACK_ALPHA};
use crate::util::bitplane::RecencyPlane;
use crate::util::decay::DecayLut;
use crate::util::fit::DoubleExp;
use crate::util::grid::Grid;
use crate::util::parallel::{auto_chunks, balanced_row_ranges, for_each_row_chunk};
use std::ops::Range;

/// Array configuration.
#[derive(Clone, Debug)]
pub struct IscConfig {
    /// Storage capacitor (selects the decay speed; 20 fF nominal).
    pub c_mem: f64,
    /// Cell-to-cell mismatch model; `None` = ideal identical cells.
    pub mismatch: Option<MismatchParams>,
    /// Separate planes per polarity (paper Sec. IV-F; costs 2× area).
    pub polarity_sensitive: bool,
    /// Maintain per-row recency bitmask words on every write (the STCF
    /// bitmask support scan reads them; see [`IscArray::recency_plane`]).
    /// Off by default so pure write/readout arrays — the router's write
    /// shards — don't pay the mark + bucket-recycle cost;
    /// `StcfBackend::isc*` constructors turn it on.
    pub recency_bitmask: bool,
    /// Size of the fitted MC bank pixels sample from.
    pub bank_size: usize,
    /// Seed for per-pixel parameter assignment.
    pub seed: u64,
    /// Global sensor row of this array's row 0. Band-sharded stages (the
    /// write router's shards, the STCF denoise pool, the serve session
    /// layer) set it to their band's first row so the position-stable
    /// mismatch assignment ([`param_index_at`]) makes the band array an
    /// exact window of the full-sensor array — sharded ≡ serial holds
    /// bit-for-bit for every shard layout, mismatch included.
    pub origin_y: u16,
}

impl Default for IscConfig {
    fn default() -> Self {
        Self {
            c_mem: 20e-15,
            mismatch: Some(MismatchParams::default()),
            polarity_sensitive: false,
            recency_bitmask: false,
            bank_size: 512,
            seed: 0x15c,
            origin_y: 0,
        }
    }
}

/// Position-stable mismatch assignment: the bank index of the cell at
/// **global** sensor position (x, y) on plane `plane` under `seed`. A
/// pure hash of (seed, plane, x, y) — independent of array shape,
/// creation order and shard layout — so a band array anchored at its
/// global rows ([`IscConfig::origin_y`]) samples exactly the per-pixel
/// decay parameters the full-sensor array holds over those rows.
#[inline]
pub fn param_index_at(seed: u64, plane: usize, x: u16, global_y: u32, bank_len: usize) -> u32 {
    // Disjoint bit fields (plane | y | x) through the SplitMix64
    // finalizer; stable forever — changing it changes every mismatch map.
    let key = (plane as u64) << 48 | (global_y as u64) << 16 | x as u64;
    let mut z = seed ^ key.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    (z % bank_len as u64) as u32
}

/// One storage plane: per-pixel write times + decay parameters + the
/// per-row lists of pixels currently inside the memory horizon.
struct Plane {
    /// Last write time in µs; 0 = never written.
    t_write: Vec<u64>,
    /// Index into the parameter bank per pixel.
    param_idx: Vec<u32>,
    /// Pixels written within the memory horizon (lazily pruned).
    active: ActiveSet,
    /// Per-row recency bitmask (window = the memory horizon), maintained
    /// on every write when [`IscConfig::recency_bitmask`] is set — the
    /// STCF bitmask support scan reads it.
    recency: Option<RecencyPlane>,
}

impl Plane {
    /// Record one write: refresh the stamp, (re-)list the pixel and set
    /// its recency bit.
    #[inline]
    fn record(&mut self, i: usize, x: u16, y: u16, t_us: u64) {
        self.t_write[i] = t_us.max(1);
        self.active.mark(x, y);
        if let Some(rp) = &mut self.recency {
            rp.mark(x, y, t_us.max(1));
        }
    }

    /// Amortized expiry scan (write path only): accrue `writes` to the
    /// scan budget and drop pixels whose age at the stream clock exceeds
    /// the readout horizon once the budget covers a full scan.
    fn maybe_prune(&mut self, writes: usize, clock_us: u64, horizon_us: u64) {
        self.active.maybe_prune_expired(writes, &self.t_write, clock_us, horizon_us);
    }

    /// Resident bytes of this plane (stamps + parameter indices +
    /// active set + optional recency bitmask).
    fn approx_bytes(&self) -> usize {
        self.t_write.capacity() * std::mem::size_of::<u64>()
            + self.param_idx.capacity() * std::mem::size_of::<u32>()
            + self.active.approx_bytes()
            + self.recency.as_ref().map_or(0, |rp| rp.approx_bytes())
    }
}

/// One readout pass of the render plan: a plane, the list-vs-dense mode
/// chosen by the [`DENSE_FALLBACK_ALPHA`] activity test, and whether the
/// pass max-merges (the OFF plane of a merged frame) or plain-stores.
struct PlanePass<'a> {
    plane: &'a Plane,
    dense: bool,
    merge: bool,
}

/// The ISC analog array.
pub struct IscArray {
    res: Resolution,
    cfg: IscConfig,
    planes: Vec<Plane>,
    /// Distinct decay parameter tuples (shared bank — cache friendly).
    bank: Vec<DoubleExp>,
    /// Quantized-decay readout kernel: one row per bank entry, 50 µs
    /// steps over the bank-derived memory horizon ⇒ ≤3.4 mV error (≪ the
    /// mismatch CV); point reads (`read`/`compare`) keep the exact
    /// closed form.
    lut: DecayLut,
    /// Latest event time ingested (the prune clock).
    clock_us: u64,
    writes: u64,
}

/// Fraction of V_dd below which a cell counts as fully decayed: the
/// readout horizon is the age at which the *slowest* bank cell crosses
/// this floor, so frames cliff to exactly 0 only where V_mem is already
/// sub-1 % (≈102 ms for the 20 fF nominal cell).
const LUT_FLOOR_FRAC: f64 = 0.01;
/// Horizon cap for cells that never cross the floor (e.g. a fit with a
/// large offset): 10 s of decay span.
const LUT_SPAN_CAP_S: f64 = 10.0;

/// A compiled fixed-threshold comparator: per-bank-entry maximum age for
/// which V_mem(Δt) ≥ V_tw still holds.
#[derive(Clone, Debug)]
pub struct Comparator {
    dt_max_us: Vec<u64>,
}

impl Comparator {
    /// Largest Δt_max across the bank — the recency window a superset
    /// structure (the [`RecencyPlane`]) must cover for "bit clear ⇒
    /// comparator fails" to hold for every cell. `u64::MAX` when some
    /// cell never decays below the threshold within the fit span (such a
    /// comparator cannot be bitmask-accelerated).
    #[inline]
    pub fn max_dt_us(&self) -> u64 {
        self.dt_max_us.iter().copied().max().unwrap_or(0)
    }

    /// Resident bytes (struct + per-bank-entry age bounds).
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>() + self.dt_max_us.capacity() * std::mem::size_of::<u64>()
    }
}

impl IscArray {
    pub fn new(res: Resolution, cfg: IscConfig) -> Self {
        let n = res.pixels();
        let bank: Vec<DoubleExp> = match &cfg.mismatch {
            Some(mm) => FittedBank::build(cfg.c_mem, mm, cfg.bank_size, cfg.seed).fits,
            None => vec![FittedBank::nominal(cfg.c_mem)],
        };
        // Precompute the frame-readout decay tables (one row per bank
        // entry) over the bank-derived memory horizon.
        let span_s = bank
            .iter()
            .map(|f| {
                f.time_to_reach(LUT_FLOOR_FRAC * VDD, LUT_SPAN_CAP_S).unwrap_or(LUT_SPAN_CAP_S)
            })
            .fold(0.0f64, f64::max)
            .max(0.01);
        let (step, bins) = DecayLut::layout_for_span(span_s * 1e6);
        let lut = DecayLut::build(bank.len(), bins, step, |row, dt_us| {
            (bank[row].eval(dt_us as f64 * 1e-6) / VDD).clamp(0.0, 1.0)
        });
        let n_planes = if cfg.polarity_sensitive { 2 } else { 1 };
        let w = res.width as usize;
        let planes = (0..n_planes)
            .map(|plane| Plane {
                t_write: vec![0u64; n],
                // Position-stable assignment: each cell hashes its global
                // (plane, x, y) position into the shared bank, so a band
                // array is an exact window of the full-sensor array.
                param_idx: (0..n)
                    .map(|i| {
                        let x = (i % w) as u16;
                        let gy = (i / w) as u32 + cfg.origin_y as u32;
                        param_index_at(cfg.seed, plane, x, gy, bank.len())
                    })
                    .collect(),
                active: ActiveSet::new(res.width as usize, res.height as usize),
                // Recency window = the readout horizon: a clear bit then
                // certifies "expired" for every comparator threshold whose
                // Δt_max fits inside the horizon (`Comparator::max_dt_us`).
                recency: cfg.recency_bitmask.then(|| {
                    RecencyPlane::new(res.width as usize, res.height as usize, lut.horizon_us())
                }),
            })
            .collect();
        Self { res, cfg, planes, bank, lut, clock_us: 0, writes: 0 }
    }

    /// Ideal array: identical nominal cells (the "full-precision" software
    /// reference uses [`crate::tsurface`] instead; this is hardware-ideal).
    pub fn ideal(res: Resolution) -> Self {
        Self::new(res, IscConfig { mismatch: None, ..IscConfig::default() })
    }

    pub fn resolution(&self) -> Resolution {
        self.res
    }

    pub fn config(&self) -> &IscConfig {
        &self.cfg
    }

    pub fn write_count(&self) -> u64 {
        self.writes
    }

    /// Age beyond which a cell's frame value reads exactly 0 (and the
    /// cell is eligible for lazy removal from the active lists).
    pub fn memory_horizon_us(&self) -> u64 {
        self.lut.horizon_us()
    }

    /// Latest event time ingested — the prune clock, and the causality
    /// bound of the activity-aware readout contract (frames at
    /// `t_us ≥ clock_us()` are exact; see [`crate::util::active`]).
    pub fn clock_us(&self) -> u64 {
        self.clock_us
    }

    /// Visit every written stamp as `f(plane, x, y, t_write)` — the
    /// checkpoint export walk of `serve::supervise`. Stamps are the
    /// complete restorable state of the array: replaying them as
    /// synthetic events in ascending-`t` order through
    /// [`IscArray::write_batch`] on a freshly built array rebuilds the
    /// clock (= the max stamp under monotone ingest), the active sets
    /// and the recency planes, and the parameter bank is
    /// position-stable ([`param_index_at`]), so the restored array
    /// renders bit-for-bit identically at every causal query time.
    pub fn for_each_stamp(&self, mut f: impl FnMut(usize, u16, u16, u64)) {
        let w = self.res.width as usize;
        for (pi, plane) in self.planes.iter().enumerate() {
            for (i, &t) in plane.t_write.iter().enumerate() {
                if t != 0 {
                    f(pi, (i % w) as u16, (i / w) as u16, t);
                }
            }
        }
    }

    /// Pixels currently listed as active on plane `p` (diagnostics).
    pub fn active_pixels(&self, p: Polarity) -> usize {
        self.planes[self.plane_for(p)].active.len()
    }

    /// The recency bitmask of the plane serving polarity `p` (window =
    /// the memory horizon; maintained on every write), present when the
    /// array was built with [`IscConfig::recency_bitmask`]. The STCF
    /// support scan popcounts it before touching any stamp (see
    /// [`crate::denoise::support_count`]).
    #[inline]
    pub fn recency_plane(&self, p: Polarity) -> Option<&RecencyPlane> {
        self.planes[self.plane_for(p)].recency.as_ref()
    }

    #[inline]
    fn plane_for(&self, p: Polarity) -> usize {
        if self.cfg.polarity_sensitive {
            p.index()
        } else {
            0
        }
    }

    /// Event write: V_mem ← V_reset via the per-pixel Cu-Cu bond. O(1)
    /// amortized; no other cell is touched (no half-select in the 3D
    /// organization) beyond the occasional active-list expiry scan.
    #[inline]
    pub fn write(&mut self, e: &Event) {
        let i = self.res.index(e.x, e.y);
        let pi = self.plane_for(e.p);
        self.clock_us = self.clock_us.max(e.t);
        let (clock, horizon) = (self.clock_us, self.lut.horizon_us());
        let plane = &mut self.planes[pi];
        plane.record(i, e.x, e.y, e.t);
        plane.maybe_prune(1, clock, horizon);
        self.writes += 1;
    }

    /// Batched event write — semantically identical to calling
    /// [`IscArray::write`] per event, but with plane selection hoisted
    /// out of the inner loop and one expiry check per batch. This is the
    /// software analogue of the plane absorbing an event burst in place,
    /// and the hot path of the sharded router.
    pub fn write_batch(&mut self, events: &[Event]) {
        let res = self.res;
        if self.cfg.polarity_sensitive {
            let [off, on] = match &mut self.planes[..] {
                [a, b] => [a, b],
                _ => unreachable!("polarity-sensitive array has two planes"),
            };
            for e in events {
                let i = res.index(e.x, e.y);
                match e.p {
                    Polarity::Off => off.record(i, e.x, e.y, e.t),
                    Polarity::On => on.record(i, e.x, e.y, e.t),
                }
            }
        } else {
            let plane = &mut self.planes[0];
            for e in events {
                plane.record(res.index(e.x, e.y), e.x, e.y, e.t);
            }
        }
        if let Some(t_max) = events.iter().map(|e| e.t).max() {
            self.clock_us = self.clock_us.max(t_max);
        }
        let (clock, horizon) = (self.clock_us, self.lut.horizon_us());
        for plane in &mut self.planes {
            plane.maybe_prune(events.len(), clock, horizon);
        }
        self.writes += events.len() as u64;
    }

    /// Analog readout of one cell at time `t_us`: the decayed V_mem in
    /// volts (0 if the cell was never written or `t` precedes the write).
    /// Exact closed form — the reference the LUT frame paths approximate.
    #[inline]
    pub fn read(&self, x: u16, y: u16, p: Polarity, t_us: u64) -> f64 {
        let plane = &self.planes[self.plane_for(p)];
        let i = self.res.index(x, y);
        let tw = plane.t_write[i];
        if tw == 0 || t_us < tw {
            return 0.0;
        }
        let dt = (t_us - tw) as f64 * 1e-6;
        self.bank[plane.param_idx[i] as usize].eval(dt).max(0.0)
    }

    /// Comparator query: V_mem ≥ v_tw? This is the single-comparator
    /// post-processing read the STCF uses (paper Fig. 10b).
    #[inline]
    pub fn compare(&self, x: u16, y: u16, p: Polarity, t_us: u64, v_tw: f64) -> bool {
        self.read(x, y, p, t_us) >= v_tw
    }

    /// Compile a fixed-threshold comparator (§Perf iteration 2): in
    /// hardware the STCF comparator has one bias V_tw, so per cell the
    /// test `V_mem(Δt) ≥ V_tw` is equivalent to `Δt ≤ Δt_max(cell)`. We
    /// precompute Δt_max per bank entry once and the hot path becomes an
    /// integer timestamp comparison — no exp() per query.
    pub fn comparator(&self, v_tw: f64) -> Comparator {
        let dt_max_us: Vec<u64> = self
            .bank
            .iter()
            .map(|f| match f.time_to_reach(v_tw, 1.0) {
                Some(t) => (t * 1e6) as u64,
                None => u64::MAX, // never decays below v_tw within horizon
            })
            .collect();
        Comparator { dt_max_us }
    }

    /// Fixed-threshold comparator query (see [`IscArray::comparator`]).
    #[inline]
    pub fn compare_with(&self, cmp: &Comparator, x: u16, y: u16, p: Polarity, t_us: u64) -> bool {
        let plane = &self.planes[self.plane_for(p)];
        let i = self.res.index(x, y);
        let tw = plane.t_write[i];
        tw != 0 && t_us >= tw && t_us - tw <= cmp.dt_max_us[plane.param_idx[i] as usize]
    }

    /// Row-sliced comparator scan: how many cells in `x0..=x1` of row `y`
    /// pass the compiled comparator at `t_us`? One contiguous walk over
    /// the stamp and parameter slices — the STCF support query issues one
    /// call per patch row instead of (2r+1)² indexed point reads.
    pub fn count_recent_in_row(
        &self,
        cmp: &Comparator,
        p: Polarity,
        y: u16,
        x0: u16,
        x1: u16,
        t_us: u64,
    ) -> u32 {
        debug_assert!(x0 <= x1 && self.res.contains(x1, y));
        let plane = &self.planes[self.plane_for(p)];
        let start = self.res.index(x0, y);
        let end = self.res.index(x1, y);
        let mut n = 0u32;
        for (&tw, &pi) in plane.t_write[start..=end].iter().zip(&plane.param_idx[start..=end]) {
            if tw != 0 && t_us >= tw && t_us - tw <= cmp.dt_max_us[pi as usize] {
                n += 1;
            }
        }
        n
    }

    /// Last write time of a cell (µs; 0 = never) — the SAE view.
    #[inline]
    pub fn last_write(&self, x: u16, y: u16, p: Polarity) -> u64 {
        self.planes[self.plane_for(p)].t_write[self.res.index(x, y)]
    }

    /// Full-frame readout at `t_us`, normalized to [0, 1] by V_dd — the
    /// time-surface the CV pipeline consumes (Fig. 6b). Hot path: the
    /// buffer is zero-filled once (vectorized), then only active pixels
    /// are evaluated through the quantized-decay LUT — O(active), no
    /// transcendentals — with an automatic dense-scan fallback above the
    /// [`DENSE_FALLBACK_ALPHA`] activity fraction and row-parallel
    /// rendering on large frames (see [`IscArray::frame_into_chunks`]).
    pub fn frame(&self, p: Polarity, t_us: u64) -> Grid<f64> {
        let mut g = Grid::new(self.res.width as usize, self.res.height as usize, 0.0f64);
        self.frame_into(p, &mut g, t_us);
        g
    }

    /// Zero-copy variant of [`IscArray::frame`]: renders into a
    /// caller-owned buffer (reshaped on first use, never reallocated on a
    /// warm buffer). This is the serving loop's per-window readout path.
    /// Large frames render row-parallel ([`crate::util::parallel`]).
    ///
    /// Exactness contract: identical to [`IscArray::frame_dense_into`]
    /// for every `t_us` ≥ the latest ingested event time (see
    /// [`crate::util::active`] for why past-facing queries may differ).
    pub fn frame_into(&self, p: Polarity, out: &mut Grid<f64>, t_us: u64) {
        self.frame_into_chunks(p, out, t_us, auto_chunks(self.res.pixels()));
    }

    /// [`IscArray::frame_into`] with an explicit row-chunk count: the
    /// rows are split into `chunks` weight-balanced ranges (per-row
    /// active counts) rendered on scoped threads. Bit-for-bit identical
    /// for every chunk count — each output row is a pure function of
    /// immutable plane state (`chunks = 1` is the single-threaded path).
    pub fn frame_into_chunks(&self, p: Polarity, out: &mut Grid<f64>, t_us: u64, chunks: usize) {
        self.render_chunked(&self.passes(false, p), out, t_us, chunks);
    }

    /// Dense reference readout: full H·W scan through the same LUT.
    pub fn frame_dense_into(&self, p: Polarity, out: &mut Grid<f64>, t_us: u64) {
        out.ensure_shape(self.res.width as usize, self.res.height as usize, 0.0);
        let plane = &self.planes[self.plane_for(p)];
        let s = out.as_mut_slice();
        for i in 0..s.len() {
            s[i] = self.lut.value(plane.param_idx[i] as usize, plane.t_write[i], t_us);
        }
    }

    /// Merged frame over both polarities (max of planes) when polarity-
    /// sensitive; identical to `frame` otherwise.
    pub fn frame_merged(&self, t_us: u64) -> Grid<f64> {
        let mut g = Grid::new(self.res.width as usize, self.res.height as usize, 0.0f64);
        self.frame_merged_into(&mut g, t_us);
        g
    }

    /// Zero-copy variant of [`IscArray::frame_merged`]: the OFF plane is
    /// max-merged directly into `out` without a scratch grid. O(active)
    /// over both planes, with the same dense fallback and row
    /// parallelism as [`IscArray::frame_into`].
    pub fn frame_merged_into(&self, out: &mut Grid<f64>, t_us: u64) {
        self.frame_merged_into_chunks(out, t_us, auto_chunks(self.res.pixels()));
    }

    /// [`IscArray::frame_merged_into`] with an explicit row-chunk count
    /// (see [`IscArray::frame_into_chunks`] for the chunking contract).
    pub fn frame_merged_into_chunks(&self, out: &mut Grid<f64>, t_us: u64, chunks: usize) {
        self.render_chunked(&self.passes(true, Polarity::On), out, t_us, chunks);
    }

    /// Forced active-list merged render (dense fallback disabled,
    /// single-threaded) — the reference the α crossover bench sweeps
    /// against [`IscArray::frame_merged_dense_into`].
    pub fn frame_merged_active_into(&self, out: &mut Grid<f64>, t_us: u64) {
        let mut passes = self.passes(true, Polarity::On);
        for pass in &mut passes {
            pass.dense = false;
        }
        self.render_chunked(&passes, out, t_us, 1);
    }

    /// Partial merged re-render of rows `rows` only — the dirty-band
    /// snapshot path: `out` must already hold this array's full merged
    /// frame at the **same** `t_us` (rows outside the range are left
    /// untouched, which is only valid when their pixels cannot have
    /// changed). O(dirty rows), single-threaded (dirty spans are small
    /// by construction).
    pub fn frame_merged_rows_into(&self, out: &mut Grid<f64>, t_us: u64, rows: Range<usize>) {
        let (w, h) = (self.res.width as usize, self.res.height as usize);
        assert!(out.width() == w && out.height() == h, "partial render needs a full-shape buffer");
        let rows = rows.start.min(h)..rows.end.min(h);
        if rows.start >= rows.end {
            return;
        }
        let passes = self.passes(true, Polarity::On);
        let slab = &mut out.as_mut_slice()[rows.start * w..rows.end * w];
        let mut scratch = Vec::new();
        self.render_slab(&passes, rows, slab, t_us, &mut scratch);
    }

    /// Build the render plan: one pass per plane, ON first (plain store),
    /// OFF max-merged on top when polarity-sensitive. Each pass picks the
    /// dense fallback independently from its plane's activity.
    fn passes(&self, merged: bool, p: Polarity) -> Vec<PlanePass<'_>> {
        let mk = |idx: usize, merge: bool| {
            let plane = &self.planes[idx];
            PlanePass { plane, dense: plane.active.denser_than(DENSE_FALLBACK_ALPHA), merge }
        };
        if merged && self.cfg.polarity_sensitive {
            vec![mk(Polarity::On.index(), false), mk(Polarity::Off.index(), true)]
        } else {
            vec![mk(self.plane_for(p), false)]
        }
    }

    /// Chunked render driver: split the rows into weight-balanced ranges
    /// and render each on its own scoped thread (inline when one chunk).
    fn render_chunked(
        &self,
        passes: &[PlanePass<'_>],
        out: &mut Grid<f64>,
        t_us: u64,
        chunks: usize,
    ) {
        let (w, h) = (self.res.width as usize, self.res.height as usize);
        out.ensure_shape(w, h, 0.0);
        let chunks = chunks.clamp(1, h);
        if chunks == 1 {
            let mut scratch = Vec::new();
            self.render_slab(passes, 0..h, out.as_mut_slice(), t_us, &mut scratch);
            return;
        }
        // Per-row work estimate: the zero-fill baseline plus each pass's
        // cost — active count for a list walk, the full width for a
        // dense scan — so threads balance under clustered activity.
        let weights: Vec<usize> = (0..h)
            .map(|y| {
                1 + passes
                    .iter()
                    .map(|pass| if pass.dense { w } else { pass.plane.active.row(y).len() })
                    .sum::<usize>()
            })
            .collect();
        let ranges = balanced_row_ranges(&weights, chunks);
        for_each_row_chunk(out, &ranges, |range, slab| {
            let mut scratch = Vec::new();
            self.render_slab(passes, range, slab, t_us, &mut scratch);
        });
    }

    /// Render rows `rows` of the pass plan into `slab` (the row-major
    /// slab covering exactly those rows). The inner loop sorts each
    /// row's active columns once and gathers the LUT over contiguous
    /// column runs — bounds-free parallel-slice walks instead of indexed
    /// scatter (§Perf: batched LUT gathers).
    fn render_slab(
        &self,
        passes: &[PlanePass<'_>],
        rows: Range<usize>,
        slab: &mut [f64],
        t_us: u64,
        scratch: &mut Vec<u16>,
    ) {
        let w = self.res.width as usize;
        debug_assert_eq!(slab.len(), (rows.end - rows.start) * w);
        // A leading dense store pass writes every cell itself.
        if !passes.first().is_some_and(|pass| pass.dense && !pass.merge) {
            slab.fill(0.0);
        }
        for pass in passes {
            let (t_write, param) = (&pass.plane.t_write[..], &pass.plane.param_idx[..]);
            for y in rows.clone() {
                let row_out = &mut slab[(y - rows.start) * w..(y - rows.start + 1) * w];
                if pass.dense {
                    let src = y * w..(y + 1) * w;
                    if pass.merge {
                        self.lut.merge_run(&param[src.clone()], &t_write[src], t_us, row_out);
                    } else {
                        self.lut.fill_run(&param[src.clone()], &t_write[src], t_us, row_out);
                    }
                    continue;
                }
                let xs = pass.plane.active.row(y);
                if xs.is_empty() {
                    continue;
                }
                for_each_sorted_run(xs, scratch, |run| {
                    let src = y * w + run.start..y * w + run.end;
                    let out_run = &mut row_out[run];
                    if pass.merge {
                        self.lut.merge_run(&param[src.clone()], &t_write[src], t_us, out_run);
                    } else {
                        self.lut.fill_run(&param[src.clone()], &t_write[src], t_us, out_run);
                    }
                });
            }
        }
    }

    /// Dense reference for [`IscArray::frame_merged_into`].
    pub fn frame_merged_dense_into(&self, out: &mut Grid<f64>, t_us: u64) {
        self.frame_dense_into(Polarity::On, out, t_us);
        if !self.cfg.polarity_sensitive {
            return;
        }
        let plane = &self.planes[Polarity::Off.index()];
        let s = out.as_mut_slice();
        for i in 0..s.len() {
            let v = self.lut.value(plane.param_idx[i] as usize, plane.t_write[i], t_us);
            if v > s[i] {
                s[i] = v;
            }
        }
    }

    /// Resident bytes of this array: per-plane stamps, parameter
    /// indices, active lists and recency bitmasks, plus the fitted bank
    /// and the shared decay LUT. The per-plane terms are O(H·W) — the
    /// cost lazy band materialization avoids paying for cold bands
    /// (see `coordinator::router::BandWriter`).
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.planes.iter().map(|p| p.approx_bytes()).sum::<usize>()
            + self.bank.capacity() * std::mem::size_of::<DoubleExp>()
            + self.lut.approx_bytes()
    }

    /// Are all planes' active sets empty *and* every write older than
    /// the memory horizon at `t_us`? When true, every cell of this
    /// array reads exactly 0 at any query time ≥ `t_us`, and (because
    /// parameter assignment is the pure position hash
    /// [`param_index_at`]) a freshly constructed array with the same
    /// config is bit-for-bit indistinguishable from this one for all
    /// future causal reads — the demotion test of lazy band
    /// materialization.
    pub fn fully_expired_at(&self, t_us: u64) -> bool {
        if t_us < self.clock_us {
            return false;
        }
        let horizon = self.lut.horizon_us();
        let w = self.res.width as usize;
        self.planes.iter().all(|p| {
            (0..p.active.height()).all(|y| {
                p.active
                    .row(y)
                    .iter()
                    .all(|&x| t_us.saturating_sub(p.t_write[y * w + x as usize]) > horizon)
            })
        })
    }

    /// Force an immediate expiry scan of the active lists (normally they
    /// are pruned lazily on the write path once the accrued write budget
    /// covers a scan). Useful before a long idle period in a serving loop.
    pub fn prune_active(&mut self) {
        let (clock, horizon) = (self.clock_us, self.lut.horizon_us());
        for plane in &mut self.planes {
            plane.active.prune_expired(&plane.t_write, clock, horizon);
        }
    }

    /// Reset all cells (power-on state).
    pub fn reset(&mut self) {
        for p in &mut self.planes {
            p.t_write.iter_mut().for_each(|t| *t = 0);
            p.active.clear();
            if let Some(rp) = &mut p.recency {
                rp.clear();
            }
        }
        self.clock_us = 0;
        self.writes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    fn small() -> IscArray {
        IscArray::new(Resolution::new(16, 12), IscConfig::default())
    }

    #[test]
    fn unwritten_reads_zero() {
        let a = small();
        assert_eq!(a.read(3, 3, Polarity::On, 1_000_000), 0.0);
        assert!(!a.compare(3, 3, Polarity::On, 1_000_000, 0.1));
    }

    #[test]
    fn fresh_write_reads_near_vdd() {
        let mut a = small();
        a.write(&Event::new(1_000, 5, 5, Polarity::On));
        let v = a.read(5, 5, Polarity::On, 1_000);
        assert!((v - VDD).abs() < 0.05, "v={v}");
    }

    #[test]
    fn decay_follows_calibration() {
        let mut a = IscArray::ideal(Resolution::new(4, 4));
        a.write(&Event::new(1_000, 0, 0, Polarity::On));
        // 10/20/30 ms later ≈ the paper's 0.72/0.46/0.30 V.
        for (dt_ms, v_ref) in [(10u64, 0.72), (20, 0.46), (30, 0.30)] {
            let v = a.read(0, 0, Polarity::On, 1_000 + dt_ms * 1_000);
            assert!((v - v_ref).abs() < 0.03, "dt={dt_ms} ms v={v}");
        }
    }

    #[test]
    fn rewrite_resets_to_vreset() {
        let mut a = small();
        a.write(&Event::new(1_000, 2, 2, Polarity::On));
        a.write(&Event::new(30_001_000, 2, 2, Polarity::On));
        let v = a.read(2, 2, Polarity::On, 30_001_000);
        assert!((v - VDD).abs() < 0.05);
    }

    #[test]
    fn polarity_planes_independent() {
        let mut a = IscArray::new(
            Resolution::new(8, 8),
            IscConfig { polarity_sensitive: true, ..IscConfig::default() },
        );
        a.write(&Event::new(5_000, 1, 1, Polarity::On));
        assert!(a.read(1, 1, Polarity::On, 5_000) > 1.0);
        assert_eq!(a.read(1, 1, Polarity::Off, 5_000), 0.0);
    }

    #[test]
    fn single_plane_merges_polarities() {
        let mut a = small();
        a.write(&Event::new(5_000, 1, 1, Polarity::Off));
        // Non-polarity-sensitive array: one plane serves both.
        assert!(a.read(1, 1, Polarity::On, 5_000) > 1.0);
    }

    #[test]
    fn frame_normalized_and_fresh_is_bright() {
        let mut a = small();
        a.write(&Event::new(10_000, 3, 4, Polarity::On));
        a.write(&Event::new(10_000 + 25_000, 8, 4, Polarity::On));
        let f = a.frame(Polarity::On, 40_000);
        assert!(f.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
        // The more recent write must be brighter (TS ordering).
        assert!(f.get(8, 4) > f.get(3, 4));
        assert_eq!(*f.get(0, 0), 0.0);
    }

    #[test]
    fn active_frame_matches_dense_reference() {
        for polarity_sensitive in [false, true] {
            let cfg = IscConfig { polarity_sensitive, ..IscConfig::default() };
            let mut a = IscArray::new(Resolution::new(16, 12), cfg);
            let events: Vec<Event> = (0..150u64)
                .map(|k| {
                    Event::new(
                        1 + k * 400,
                        (k % 16) as u16,
                        (k % 12) as u16,
                        if k % 3 == 0 { Polarity::Off } else { Polarity::On },
                    )
                })
                .collect();
            a.write_batch(&events);
            let t = events.last().unwrap().t + 2_000;
            let mut active = Grid::new(1, 1, 0.0);
            let mut dense = Grid::new(1, 1, 0.0);
            a.frame_merged_into(&mut active, t);
            a.frame_merged_dense_into(&mut dense, t);
            assert_eq!(active, dense);
        }
    }

    #[test]
    fn cells_expire_past_memory_horizon() {
        // Ideal (nominal) array: the horizon is this single cell's own
        // 1 %-of-V_dd crossing, so just inside it the frame value is
        // still ≈1 % and at the horizon it reads exactly 0.
        let mut a = IscArray::ideal(Resolution::new(16, 12));
        a.write(&Event::new(1_000, 3, 3, Polarity::On));
        let horizon = a.memory_horizon_us();
        // dt = horizon − 1 lands in the last LUT bin whatever the step.
        assert!(*a.frame(Polarity::On, 1_000 + horizon - 1).get(3, 3) > 0.0);
        assert_eq!(*a.frame(Polarity::On, 1_000 + horizon).get(3, 3), 0.0);
    }

    #[test]
    fn explicit_prune_drops_expired_cells_and_keeps_readout_exact() {
        let res = Resolution::new(64, 64);
        let mut a = IscArray::new(res, IscConfig::default());
        let horizon = a.memory_horizon_us();
        for k in 0..600u64 {
            a.write(&Event::new(1 + k, (k % 64) as u16, (k / 64) as u16, Polarity::On));
        }
        assert_eq!(a.active_pixels(Polarity::On), 600);
        // One fresh write far past the horizon, then force the scan:
        // every stale cell is dropped, the fresh one stays.
        a.write(&Event::new(horizon * 3, 0, 0, Polarity::On));
        a.prune_active();
        assert_eq!(a.active_pixels(Polarity::On), 1);
        // Readout stays exact after pruning.
        let t = horizon * 3 + 100;
        let mut active = Grid::new(1, 1, 0.0);
        let mut dense = Grid::new(1, 1, 0.0);
        a.frame_merged_into(&mut active, t);
        a.frame_merged_dense_into(&mut dense, t);
        assert_eq!(active, dense);
    }

    #[test]
    fn budget_prune_triggers_on_write_path() {
        // 256 distinct stale pixels (rows 0..4), then a long burst of
        // rewrites confined to an 8×8 region far past the horizon: once
        // the write budget covers a scan, the expired 256 must drop out
        // without any explicit prune call.
        let res = Resolution::new(64, 64);
        let mut a = IscArray::new(res, IscConfig::default());
        let horizon = a.memory_horizon_us();
        for k in 0..256u64 {
            a.write(&Event::new(1 + k, (k % 64) as u16, (k / 64) as u16, Polarity::On));
        }
        for k in 0..600u64 {
            a.write(&Event::new(
                horizon * 2 + k,
                (k % 8) as u16,
                (32 + (k / 8) % 8) as u16,
                Polarity::On,
            ));
        }
        assert_eq!(
            a.active_pixels(Polarity::On),
            64,
            "expired cells must be pruned by the write-budget scan"
        );
    }

    #[test]
    fn chunked_render_identical_for_any_chunk_count() {
        for polarity_sensitive in [false, true] {
            let cfg = IscConfig { polarity_sensitive, ..IscConfig::default() };
            let mut a = IscArray::new(Resolution::new(24, 13), cfg);
            let events: Vec<Event> = (0..400u64)
                .map(|k| {
                    Event::new(
                        1 + k * 90,
                        (k % 24) as u16,
                        ((k * 7) % 13) as u16,
                        if k % 2 == 0 { Polarity::Off } else { Polarity::On },
                    )
                })
                .collect();
            a.write_batch(&events);
            let t = events.last().unwrap().t + 500;
            let mut reference = Grid::new(1, 1, 0.0);
            a.frame_merged_into_chunks(&mut reference, t, 1);
            // 2 and 8 chunks, plus more chunks than rows (13 rows).
            for chunks in [2usize, 8, 64] {
                let mut chunked = Grid::new(1, 1, 0.0);
                a.frame_merged_into_chunks(&mut chunked, t, chunks);
                assert_eq!(chunked, reference, "merged, chunks={chunks}");
                a.frame_into_chunks(Polarity::On, &mut chunked, t, chunks);
                let mut single = Grid::new(1, 1, 0.0);
                a.frame_into_chunks(Polarity::On, &mut single, t, 1);
                assert_eq!(chunked, single, "on-plane, chunks={chunks}");
            }
        }
    }

    #[test]
    fn dense_fallback_engages_and_matches_both_references() {
        // 100 % activity: every pixel written ⇒ the α test must flip the
        // render to the dense scan, and all three paths must agree at a
        // causal query time.
        let res = Resolution::new(20, 15);
        let cfg = IscConfig { polarity_sensitive: true, ..IscConfig::default() };
        let mut a = IscArray::new(res, cfg);
        let events: Vec<Event> = (0..res.pixels() as u64)
            .map(|k| {
                Event::new(
                    1 + k,
                    (k % 20) as u16,
                    (k / 20) as u16,
                    if k % 3 == 0 { Polarity::Off } else { Polarity::On },
                )
            })
            .collect();
        a.write_batch(&events);
        assert!(a.planes[0].active.denser_than(crate::util::active::DENSE_FALLBACK_ALPHA));
        let t = events.last().unwrap().t + 1_000;
        let (mut auto_f, mut dense, mut active) =
            (Grid::new(1, 1, 0.0), Grid::new(1, 1, 0.0), Grid::new(1, 1, 0.0));
        a.frame_merged_into(&mut auto_f, t);
        a.frame_merged_dense_into(&mut dense, t);
        a.frame_merged_active_into(&mut active, t);
        assert_eq!(auto_f, dense);
        assert_eq!(auto_f, active);
    }

    #[test]
    fn partial_rows_render_matches_full_rerender() {
        for polarity_sensitive in [false, true] {
            let cfg = IscConfig { polarity_sensitive, ..IscConfig::default() };
            let mut a = IscArray::new(Resolution::new(16, 12), cfg);
            let warm: Vec<Event> = (0..80u64)
                .map(|k| Event::new(1 + k * 600, (k % 16) as u16, (k % 12) as u16, Polarity::On))
                .collect();
            a.write_batch(&warm);
            let t = 60_000u64;
            let mut buf = Grid::new(1, 1, 0.0);
            a.frame_merged_into(&mut buf, t);
            // New writes confined to rows 3..6, still causal for t.
            let dirty: Vec<Event> = (0..30u64)
                .map(|k| {
                    Event::new(55_000 + k, (k % 16) as u16, (3 + k % 3) as u16, Polarity::Off)
                })
                .collect();
            a.write_batch(&dirty);
            a.frame_merged_rows_into(&mut buf, t, 3..6);
            assert_eq!(buf, a.frame_merged(t), "ps={polarity_sensitive}");
        }
    }

    #[test]
    fn band_array_is_exact_window_of_full_sensor_array() {
        // Position-stable mismatch assignment: an array covering rows
        // y0..y0+rows with `origin_y: y0` must hold exactly the decay
        // parameters the full-sensor array assigns to those rows, so
        // identical writes read identical voltages — bit for bit, on
        // both planes, for any band placement.
        for polarity_sensitive in [false, true] {
            let cfg = IscConfig { polarity_sensitive, ..IscConfig::default() };
            let res = Resolution::new(16, 12);
            let mut full = IscArray::new(res, cfg.clone());
            for y0 in [0u16, 3, 7, 11] {
                let rows = 4u16.min(12 - y0);
                let band_cfg = IscConfig { origin_y: y0, ..cfg.clone() };
                let mut band = IscArray::new(Resolution::new(16, rows), band_cfg);
                full.reset();
                for k in 0..(16 * rows as u64) {
                    let (x, dy) = ((k % 16) as u16, (k / 16) as u16);
                    let p = if k % 3 == 0 { Polarity::Off } else { Polarity::On };
                    let t = 1_000 + k * 37;
                    full.write(&Event::new(t, x, y0 + dy, p));
                    band.write(&Event::new(t, x, dy, p));
                }
                for k in 0..(16 * rows as u64) {
                    let (x, dy) = ((k % 16) as u16, (k / 16) as u16);
                    for dt in [0u64, 7_000, 31_000] {
                        let t = 1_000 + 16 * rows as u64 * 37 + dt;
                        assert_eq!(
                            full.read(x, y0 + dy, Polarity::On, t),
                            band.read(x, dy, Polarity::On, t),
                            "y0={y0} ({x},{dy}) dt={dt} ps={polarity_sensitive}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn param_index_at_is_shape_independent_and_in_range() {
        for seed in [0u64, 0x15c, u64::MAX / 3] {
            for bank_len in [1usize, 32, 512] {
                for plane in [0usize, 1] {
                    let a = param_index_at(seed, plane, 13, 1_000, bank_len);
                    assert!(a < bank_len as u32);
                    // Pure function of the global position.
                    assert_eq!(a, param_index_at(seed, plane, 13, 1_000, bank_len));
                }
            }
        }
        // Planes draw independent maps (polarity-sensitive arrays must
        // not mirror their mismatch across planes).
        let differs = (0..64u32)
            .any(|y| param_index_at(7, 0, 3, y, 512) != param_index_at(7, 1, 3, y, 512));
        assert!(differs);
    }

    #[test]
    fn mismatch_makes_pixels_differ_slightly() {
        let mut a = small();
        let t0 = 1_000u64;
        for x in 0..16u16 {
            a.write(&Event::new(t0, x, 0, Polarity::On));
        }
        let t = t0 + 30_000; // 30 ms: CV ≈ 1 % band
        let vals: Vec<f64> = (0..16).map(|x| a.read(x, 0, Polarity::On, t)).collect();
        let cv = crate::util::stats::cv_percent(&vals);
        assert!(cv > 0.05, "expected visible mismatch, cv={cv}%");
        assert!(cv < 5.0, "mismatch too large, cv={cv}%");
    }

    #[test]
    fn prop_read_bounded_and_monotone_in_time() {
        check("isc read bounded+monotone", 60, |g| {
            let mut a = IscArray::new(
                Resolution::new(8, 8),
                IscConfig { seed: g.u64(0, u64::MAX / 2), ..IscConfig::default() },
            );
            let x = g.u64(0, 7) as u16;
            let y = g.u64(0, 7) as u16;
            let t0 = g.u64(1, 1_000_000);
            a.write(&Event::new(t0, x, y, Polarity::On));
            let mut prev = f64::INFINITY;
            let mut t = t0;
            for _ in 0..12 {
                t += g.u64(100, 5_000);
                let v = a.read(x, y, Polarity::On, t);
                assert!((0.0..=VDD * 1.02).contains(&v), "v={v}");
                assert!(v <= prev + 1e-9, "decay must be monotone");
                prev = v;
            }
        });
    }

    #[test]
    fn write_batch_equals_single_writes() {
        for polarity_sensitive in [false, true] {
            let cfg = IscConfig { polarity_sensitive, ..IscConfig::default() };
            let mut a = IscArray::new(Resolution::new(16, 12), cfg.clone());
            let mut b = IscArray::new(Resolution::new(16, 12), cfg);
            let events: Vec<Event> = (0..200u64)
                .map(|k| {
                    Event::new(
                        1 + k * 97,
                        (k % 16) as u16,
                        (k % 12) as u16,
                        if k % 3 == 0 { Polarity::Off } else { Polarity::On },
                    )
                })
                .collect();
            for e in &events {
                a.write(e);
            }
            b.write_batch(&events);
            assert_eq!(a.write_count(), b.write_count());
            assert_eq!(a.frame_merged(30_000), b.frame_merged(30_000));
        }
    }

    #[test]
    fn frame_into_reuses_buffer() {
        let mut a = small();
        a.write(&Event::new(1_000, 3, 3, Polarity::On));
        let mut buf = Grid::new(1, 1, 0.0);
        a.frame_merged_into(&mut buf, 2_000); // warmup: reshapes once
        let ptr = buf.as_slice().as_ptr();
        for dt in 1..10u64 {
            a.frame_merged_into(&mut buf, 2_000 + dt * 5_000);
            assert_eq!(buf.as_slice().as_ptr(), ptr, "warm frame_into must not reallocate");
        }
        assert_eq!(buf, a.frame_merged(2_000 + 9 * 5_000));
    }

    #[test]
    fn merged_into_matches_two_plane_max() {
        let mut a = IscArray::new(
            Resolution::new(8, 8),
            IscConfig { polarity_sensitive: true, ..IscConfig::default() },
        );
        a.write(&Event::new(1_000, 1, 1, Polarity::On));
        a.write(&Event::new(9_000, 1, 1, Polarity::Off));
        a.write(&Event::new(5_000, 6, 2, Polarity::On));
        let t = 20_000;
        let merged = a.frame_merged(t);
        let on = a.frame(Polarity::On, t);
        let off = a.frame(Polarity::Off, t);
        for (x, y, &v) in merged.iter_coords() {
            assert_eq!(v, on.get(x, y).max(*off.get(x, y)));
        }
    }

    #[test]
    fn count_recent_in_row_matches_compare_with() {
        let mut a = small();
        a.write_batch(&[
            Event::new(1_000, 2, 5, Polarity::On),
            Event::new(2_000, 4, 5, Polarity::On),
            Event::new(90_000, 9, 5, Polarity::On),
        ]);
        let cmp = a.comparator(0.4);
        let t = 25_000u64;
        let by_row = a.count_recent_in_row(&cmp, Polarity::On, 5, 0, 15, t);
        let by_point: u32 =
            (0..16u16).filter(|&x| a.compare_with(&cmp, x, 5, Polarity::On, t)).count() as u32;
        assert_eq!(by_row, by_point);
    }

    #[test]
    fn recency_bits_follow_writes_and_cover_the_comparator() {
        // Off by default: the router's write shards never pay for it.
        assert!(small().recency_plane(Polarity::On).is_none());
        let cfg = IscConfig { recency_bitmask: true, ..IscConfig::default() };
        let mut a = IscArray::new(Resolution::new(16, 12), cfg);
        a.write(&Event::new(1_000, 3, 4, Polarity::On));
        let rp = a.recency_plane(Polarity::On).unwrap();
        assert!(rp.covers(a.memory_horizon_us()));
        assert_eq!(rp.popcount_window(4, 0, 15, 2_000), 1);
        assert_eq!(rp.popcount_window(5, 0, 15, 2_000), 0);
        // Any in-horizon comparator threshold is bitmask-coverable: each
        // cell crosses v_tw strictly before its 1 %-of-V_dd horizon.
        let cmp = a.comparator(0.4);
        assert!(cmp.max_dt_us() <= a.memory_horizon_us());
        assert!(rp.covers(cmp.max_dt_us()));
        a.reset();
        let rp = a.recency_plane(Polarity::On).unwrap();
        assert_eq!(rp.popcount_window(4, 0, 15, 2_000), 0);
    }

    #[test]
    fn fully_expired_tracks_horizon_and_fresh_array_is_equivalent() {
        let cfg = IscConfig { polarity_sensitive: true, ..IscConfig::default() };
        let mut a = IscArray::new(Resolution::new(8, 6), cfg.clone());
        assert!(a.fully_expired_at(0), "unwritten array is trivially expired");
        a.write(&Event::new(1_000, 2, 3, Polarity::Off));
        let horizon = a.memory_horizon_us();
        assert!(!a.fully_expired_at(1_000 + horizon), "conservative at exactly the horizon");
        assert!(!a.fully_expired_at(500), "non-causal query must answer false");
        assert!(a.fully_expired_at(1_001 + horizon));
        // The demotion law: once fully expired, a fresh array with the
        // same config serves identical causal frames.
        let fresh = IscArray::new(Resolution::new(8, 6), cfg);
        let t = 1_001 + horizon;
        assert_eq!(a.frame_merged(t), fresh.frame_merged(t));
    }

    #[test]
    fn approx_bytes_counts_the_planes() {
        let a = small();
        let b = IscArray::new(
            Resolution::new(16, 12),
            IscConfig { polarity_sensitive: true, ..IscConfig::default() },
        );
        let base = a.approx_bytes();
        assert!(base > 16 * 12 * (8 + 4), "must cover stamps + param indices");
        assert!(b.approx_bytes() > base, "two planes cost more than one");
    }

    #[test]
    fn reset_clears_state() {
        let mut a = small();
        a.write(&Event::new(1_000, 2, 3, Polarity::On));
        a.reset();
        assert_eq!(a.read(2, 3, Polarity::On, 2_000), 0.0);
        assert_eq!(a.write_count(), 0);
        assert_eq!(a.active_pixels(Polarity::On), 0);
    }
}
