//! END-TO-END VALIDATION DRIVER (DESIGN.md §4, EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the system on a real small workload:
//!
//!   synthetic N-MNIST-like event dataset (events::dataset)
//!     → coordinator pipeline: sharded router + 50 ms frame scheduler
//!     → ISC analog-array time surfaces (circuit-calibrated, mismatched)
//!     → AOT `classifier_train` artifact (JAX/Pallas → HLO → PJRT)
//!       executed in a Rust training loop for a few hundred steps
//!     → loss curve + frame/video accuracy, ideal-TS vs ISC-TS.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example classify_e2e [-- --steps 300]

use tsisc::cli::Args;
use tsisc::events::dataset::{generate, Family, GenOptions};
use tsisc::isc::IscConfig;
use tsisc::runtime::{artifacts_available, default_artifact_dir, Runtime};
use tsisc::train::driver::{train_classifier, TrainConfig};
use tsisc::train::frames::{dataset_frames, SurfaceKind};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    if !artifacts_available() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let steps = args.get_parsed("steps", 300usize);
    let per_class = args.get_parsed("per-class", 24usize);

    eprintln!("[1/4] generating synthetic N-MNIST-like dataset ({per_class}/class train)...");
    let ds = generate(
        Family::NMnist,
        GenOptions {
            train_per_class: per_class,
            test_per_class: 8,
            duration_s: 0.15,
            noise_hz: 1.0,
            seed: 7,
        },
    );
    let n_events: usize = ds.train.iter().map(|s| s.events.len()).sum();
    eprintln!(
        "      {} train / {} test samples, {} train events",
        ds.train.len(),
        ds.test.len(),
        n_events
    );

    let mut rt = Runtime::new(default_artifact_dir()).expect("PJRT runtime");
    eprintln!("[2/4] PJRT platform: {}", rt.platform());

    let cfg = TrainConfig { steps, lr: 0.03, seed: 42, log_every: 20 };
    let mut results = Vec::new();
    for (name, kind) in [
        ("ideal-TS", SurfaceKind::Ideal { tau_us: 24_000.0 }),
        ("3DS-ISC", SurfaceKind::Isc(IscConfig::default())),
    ] {
        eprintln!("[3/4] building {name} frames (50 ms windows → 32x32)...");
        let (train, test) = dataset_frames(&ds, &kind, 50_000, 32);
        eprintln!(
            "      {} train frames, {} test frames; training {steps} steps...",
            train.frames.len(),
            test.frames.len()
        );
        let r = train_classifier(&mut rt, &train, &test, &cfg).expect("train");
        println!("--- {name} loss curve ---");
        for (step, loss) in &r.loss_curve {
            println!("step {step:>5}  loss {loss:.4}");
        }
        println!(
            "{name}: final loss {:.4}, frame acc {:.3}, video acc {:.3}",
            r.final_loss, r.frame_accuracy, r.video_accuracy
        );
        results.push((name, r));
    }

    println!("\n[4/4] === end-to-end summary (paper Table II parity claim) ===");
    println!("{:<10} {:>12} {:>12} {:>12}", "input", "final loss", "frame acc", "video acc");
    for (name, r) in &results {
        println!(
            "{:<10} {:>12.4} {:>12.3} {:>12.3}",
            name, r.final_loss, r.frame_accuracy, r.video_accuracy
        );
    }
    let gap = results[0].1.video_accuracy - results[1].1.video_accuracy;
    println!(
        "\nhardware-vs-ideal video accuracy gap: {gap:+.3} \
         (paper: ≈0 — the analog TS preserves the temporal information)"
    );
}
