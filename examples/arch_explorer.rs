//! Architecture design-space explorer (paper Sec. IV-B).
//!
//! Sweeps C_mem, sensor resolution and event rate through the circuit and
//! architecture models; prints the power/area/delay frontier and where the
//! paper's design point (20 fF, QVGA, 100 Meps) sits.
//! Run: `cargo run --release --example arch_explorer`

use tsisc::arch::arch3d::Workload;
use tsisc::arch::{arch2d, arch3d, ArchReport, ArrayGeometry};
use tsisc::circuit::cell::{CellSim, LeakageMacro, V_FLOOR};
use tsisc::events::Resolution;

fn main() {
    // --- C_mem sweep: memory window vs area ---------------------------
    println!("C_mem sweep (LL switch):");
    println!("{:>8} {:>14} {:>12} {:>10}", "C (fF)", "window (ms)", "cell (µm²)", ">=24 ms");
    let leak = LeakageMacro::ll_calibrated();
    for c_ff in [5.0, 10.0, 15.0, 20.0, 30.0, 40.0] {
        let w = CellSim::new(c_ff * 1e-15, leak).memory_window(V_FLOOR, 0.5);
        // MOMCAP density fixes the area/capacitance trade (Fig. 4f).
        let area = c_ff * 1e-15 / tsisc::circuit::params::MOMCAP_DENSITY_F_PER_UM2;
        println!(
            "{:>8.0} {:>14.1} {:>12.1} {:>10}",
            c_ff,
            w * 1e3,
            area,
            if w >= 24e-3 { "yes" } else { "no" }
        );
    }

    // --- resolution sweep: 2D/3D ratios hold across geometries ---------
    println!("\nresolution sweep (100 Meps, 20 fF):");
    println!(
        "{:>12} {:>12} {:>12} {:>12}",
        "resolution", "P ratio", "A ratio", "D ratio"
    );
    for (name, res) in [
        ("128x128", Resolution::new(128, 128)),
        ("QVGA", Resolution::QVGA),
        ("DAVIS346", Resolution::DAVIS346),
        ("VGA", Resolution::new(640, 480)),
    ] {
        let g = ArrayGeometry::new(res);
        let w = Workload::default();
        let (p, a, d) = ArchReport::ratios(&arch2d::report(&g, &w), &arch3d::report(&g, &w));
        println!("{name:>12} {p:>11.1}x {a:>11.2}x {d:>11.2}x");
    }

    // --- event-rate sweep: where static power takes over ---------------
    println!("\nevent-rate sweep (QVGA, 3D):");
    println!("{:>12} {:>14} {:>16}", "rate (Meps)", "power (µW)", "static share (%)");
    let g = ArrayGeometry::new(Resolution::QVGA);
    for rate in [1.0, 10.0, 50.0, 100.0, 300.0] {
        let w = Workload { event_rate: rate * 1e6, frame_rate: 20.0 };
        let r = arch3d::report(&g, &w);
        println!(
            "{rate:>12.0} {:>14.3} {:>16.2}",
            r.power.total() * 1e6,
            r.power.share_percent("isc-array static")
        );
    }
    println!("\npaper design point: 20 fF, QVGA, 100 Meps -> 69x / 1.9x / 2.2x vs 2D");
}
