//! STCF denoising demo (paper Sec. IV-C / Fig. 10).
//!
//! Contaminates a driving-like stream with 5 Hz/pixel background-activity
//! noise, runs the STCF on (a) full-precision timestamps and (b) the ISC
//! analog array with its single-comparator readout, and reports ROC/AUC.
//! Run: `cargo run --release --example denoise_demo`

use tsisc::circuit::MismatchParams;
use tsisc::denoise::{run_stcf, StcfBackend, StcfParams};
use tsisc::events::noise::contaminate;
use tsisc::events::scene::EdgeScene;
use tsisc::events::v2e::{convert, DvsParams};
use tsisc::events::Resolution;
use tsisc::isc::IscConfig;
use tsisc::metrics::{roc, BinaryStats};

fn main() {
    let res = Resolution::new(64, 64);
    let dur = 1.0;
    let scene = EdgeScene::new(90.0, 21);
    let signal = convert(&scene, res, DvsParams::default(), dur);
    let noisy = contaminate(&signal, res, 5.0, dur, 17);
    println!(
        "stream: {} signal + {} noise events",
        signal.len(),
        noisy.len() - signal.len()
    );

    let prm = StcfParams::default();
    println!(
        "STCF: r={}, tau_tw={} ms, threshold={}",
        prm.radius,
        prm.tau_tw_us / 1000,
        prm.threshold
    );

    // (a) ideal: digital timestamp comparison t - T(u) <= tau.
    let mut ideal = StcfBackend::ideal(res);
    let run_i = run_stcf(&mut ideal, &noisy, &prm);
    let roc_i = roc(&run_i.scored);

    // (b) hardware: analog comparator V_mem >= V_tw on the mismatched array.
    let cfg = IscConfig { mismatch: Some(MismatchParams::default()), ..IscConfig::default() };
    let mut hw = StcfBackend::isc(res, cfg, prm.tau_tw_us);
    let run_h = run_stcf(&mut hw, &noisy, &prm);
    let roc_h = roc(&run_h.scored);

    println!("ideal TS    : AUC = {:.3}", roc_i.auc);
    println!("ISC (20 fF) : AUC = {:.3}", roc_h.auc);

    let stats = BinaryStats::from_scored(&run_h.scored, prm.threshold as f64);
    println!(
        "at threshold {}: TPR {:.3}, FPR {:.3}, precision {:.3}, F1 {:.3}",
        prm.threshold,
        stats.tpr(),
        stats.fpr(),
        stats.precision(),
        stats.f1()
    );
    println!(
        "kept {}/{} events ({} noise leaked)",
        run_h.kept.len(),
        noisy.len(),
        run_h.kept.iter().filter(|e| !e.is_signal).count()
    );
}
