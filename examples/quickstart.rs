//! Quickstart: the smallest possible tour of the 3DS-ISC API.
//!
//! Generates a moving-scene event stream, feeds it through the simulated
//! analog ISC array, and prints time-surface statistics plus an ASCII
//! rendering. Run: `cargo run --release --example quickstart`

use tsisc::events::scene::BlobScene;
use tsisc::events::v2e::{convert, DvsParams};
use tsisc::events::Resolution;
use tsisc::isc::{IscArray, IscConfig};

fn main() {
    // 1. A synthetic scene: two wandering blobs over a 64x64 sensor.
    let res = Resolution::new(64, 64);
    let scene = BlobScene::new(64, 64, 2, 1.0, 42);

    // 2. DVS conversion: temporal-contrast events (v2e-style).
    let events = convert(&scene, res, DvsParams::default(), 1.0);
    println!("generated {} events over 1 s", events.len());

    // 3. The ISC analog array: one 6T-1C eDRAM cell per pixel, with
    //    Monte-Carlo cell-to-cell variability (paper Sec. IV-A).
    //    Ingestion is batch-first: write_batch absorbs bounded bursts of
    //    events (per-pixel Cu-Cu writes: O(1) each, no half-select).
    let mut array = IscArray::new(res, IscConfig::default());
    let mut staged = Vec::with_capacity(4_096.min(events.len()));
    for part in events.chunks(4_096) {
        staged.clear();
        staged.extend(part.iter().map(|le| le.ev));
        array.write_batch(&staged);
    }

    // 4. Read the self-normalized time surface at the end of the stream.
    let t_end = 1_000_000;
    let frame = array.frame_merged(t_end);
    let bright = frame.as_slice().iter().filter(|&&v| v > 0.5).count();
    let written = frame.as_slice().iter().filter(|&&v| v > 0.0).count();
    println!(
        "time surface: {written}/{} pixels written, {bright} recent (V > 0.5*Vdd)",
        res.pixels()
    );

    // 5. ASCII view (bright = recent events).
    let ramp = b" .:-=+*#%@";
    for y in (0..64).step_by(2) {
        let row: String = (0..64)
            .map(|x| {
                let v = *frame.get(x, y);
                ramp[((v * (ramp.len() - 1) as f64) as usize).min(ramp.len() - 1)] as char
            })
            .collect();
        println!("{row}");
    }
    println!("done — see examples/denoise_demo.rs and examples/classify_e2e.rs next");
}
