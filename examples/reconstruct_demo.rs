//! Image-reconstruction demo (paper Sec. IV-E / Table III).
//!
//! Records a synthetic DAVIS sequence (paired events + APS frames), builds
//! TS inputs from the ISC analog array, trains the AOT UNet-lite artifact
//! and reports SSIM vs the event-count baseline input.
//! Requires `make artifacts`. Run:
//!   cargo run --release --example reconstruct_demo

use tsisc::events::davis::{record, SEQUENCES};
use tsisc::events::Resolution;
use tsisc::isc::IscConfig;
use tsisc::recon::{build_pairs, train_recon, ReconConfig};
use tsisc::runtime::{artifacts_available, default_artifact_dir, Runtime};
use tsisc::train::frames::SurfaceKind;

fn main() {
    if !artifacts_available() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let mut rt = Runtime::new(default_artifact_dir()).expect("runtime");

    // Use the rotation-dominant sequence (the paper's best case for the
    // analog TS: shapes_6dof, SSIM 0.91).
    let (name, motion) = SEQUENCES[5];
    eprintln!("recording synthetic '{name}' (64x64, 1.5 s, 30 fps)...");
    let rec = record(name, motion, Resolution::new(64, 64), 1.5, 30.0, 13);
    eprintln!("{} events, {} APS frames", rec.events.len(), rec.frames.len());

    let cfg = ReconConfig { steps: 150, lr: 0.15, seed: 7, holdout_every: 4 };
    for (label, kind) in [
        ("3D-ISC TS", SurfaceKind::Isc(IscConfig::default())),
        ("event-count", SurfaceKind::Count { bits: 4 }),
    ] {
        let pairs = build_pairs(&rec, &kind);
        let r = train_recon(&mut rt, &pairs, &cfg).expect("train");
        println!("--- {label} ---");
        for (step, loss) in &r.loss_curve {
            println!("  step {step:>4} loss {loss:.5}");
        }
        println!(
            "  final loss {:.5}, held-out SSIM {:.3} over {} frames",
            r.final_loss, r.mean_ssim, r.n_eval
        );
    }
    println!("\npaper: 3D-ISC reaches mean SSIM 0.62 (best of three methods).");
}
