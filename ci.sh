#!/usr/bin/env bash
# Tier-1 CI gate: release build + tests + quick bench snapshot.
#
# Emits BENCH_tsurface.json (ingest throughput, dense-vs-active readout,
# the thread-count sweep with frames_per_sec and the dense-fallback α
# crossover), BENCH_router.json (routing throughput + dirty-band
# snapshot frames_per_sec), BENCH_denoise.json (support-scan tier
# sweep + denoise-shard scaling, events_per_sec) and BENCH_serve.json
# (multi-tenant sessions × workers sweep, aggregate events_per_sec +
# snapshot_p99_ms) at the repo root so successive PRs can be compared.
set -uo pipefail

cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found — Rust toolchain unavailable in this environment." >&2
    echo "ci.sh: skipping build/test/bench (tier-1 must run where rustup is installed)." >&2
    exit 1
fi

if [ ! -f rust/Cargo.toml ]; then
    # The seed ships no manifest (deps `anyhow`/`xla` are unvendored), so
    # tier-1 has been failing since PR 0 for reasons outside any one
    # change. Report a loud SKIP instead of a permanently red gate; the
    # moment a Cargo.toml lands (remember `[[bench]] harness = false`
    # entries for rust/benches/*.rs, which define their own `fn main`),
    # this script becomes the real build/test/bench gate with no further
    # workflow edits.
    echo "ci.sh: SKIP — rust/Cargo.toml does not exist yet (seed state)." >&2
    echo "ci.sh: add the manifest to turn this gate on." >&2
    exit 0
fi

set -e
echo "== cargo build --release =="
(cd rust && cargo build --release)

echo "== cargo test -q =="
(cd rust && cargo test -q)

echo "== lint (cargo fmt --check + clippy -D warnings) =="
if cargo fmt --version >/dev/null 2>&1 && cargo clippy --version >/dev/null 2>&1; then
    make lint
else
    echo "ci.sh: rustfmt/clippy components unavailable — skipping lint." >&2
fi

echo "== cargo bench (quick) =="
(cd rust && cargo bench -- --quick)

for snap in BENCH_tsurface.json BENCH_router.json BENCH_denoise.json BENCH_serve.json; do
    if [ -f "rust/$snap" ]; then
        cp "rust/$snap" "$snap"
        echo "== bench snapshot: $snap =="
        cat "$snap"
    else
        echo "ci.sh: warning — rust/$snap was not produced" >&2
    fi
done
