#!/usr/bin/env bash
# Tier-1 CI gate: release build + tests + lint + quick bench snapshot.
#
# Emits BENCH_tsurface.json (ingest throughput, dense-vs-active readout,
# the thread-count sweep with frames_per_sec and the dense-fallback α
# crossover), BENCH_router.json (routing throughput + dirty-band
# snapshot frames_per_sec), BENCH_denoise.json (support-scan tier
# sweep + denoise-shard scaling, events_per_sec) and BENCH_serve.json
# (multi-tenant sessions × workers sweep, aggregate events_per_sec +
# snapshot_p99_us, the per-stage telemetry p99s off the fleet's
# observability plane, the idle-fleet memory sweep's
# resident_bytes_per_session at 1/10/100 % duty, and the wire-mode
# loopback-TCP round trip's wire_to_snapshot_p99_us) at the repo root
# so successive PRs can be compared — `cargo xtask bench-compare
# OLD.json NEW.json` diffs two snapshots and fails on >20% drift.
# A missing or empty snapshot is a hard failure — a bench binary that
# silently stopped emitting its JSON would otherwise erase the perf
# trajectory without anyone noticing.
#
# Deeper gates (loom, miri, tsan) run as separate CI jobs; see the
# Makefile targets of the same names.
set -uo pipefail

cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found — Rust toolchain unavailable in this environment." >&2
    echo "ci.sh: skipping build/test/bench (tier-1 must run where rustup is installed)." >&2
    exit 1
fi

if [ ! -f rust/Cargo.toml ]; then
    echo "ci.sh: FAIL — rust/Cargo.toml is missing (the workspace manifest is committed; a checkout without it is broken)." >&2
    exit 1
fi

set -e
echo "== cargo build --release --workspace =="
cargo build --release --workspace

echo "== cargo test -q --workspace =="
cargo test -q --workspace

echo "== cargo xtask lint-invariants =="
cargo run --quiet --package xtask -- lint-invariants

echo "== lint (cargo fmt --all --check + clippy --workspace -D warnings) =="
if cargo fmt --version >/dev/null 2>&1 && cargo clippy --version >/dev/null 2>&1; then
    make lint
else
    echo "ci.sh: rustfmt/clippy components unavailable — skipping lint." >&2
fi

echo "== cargo bench (quick) =="
(cd rust && cargo bench -- --quick)

# Advisory perf-trajectory diff: the repo root still holds the previous
# run's serve snapshot at this point (the copy below overwrites it), so
# compare old vs new before copying. Never fails CI (perf noise on
# shared runners), but the report lands in the log so drift is visible
# PR over PR.
if [ -s BENCH_serve.json ] && [ -s rust/BENCH_serve.json ] \
   && ! cmp -s BENCH_serve.json rust/BENCH_serve.json; then
    echo "== cargo xtask bench-compare (advisory, vs previous snapshot) =="
    cargo run --quiet --package xtask -- bench-compare BENCH_serve.json rust/BENCH_serve.json \
        || echo "ci.sh: bench-compare reported drift (advisory only)" >&2
fi

fail=0
for snap in BENCH_tsurface.json BENCH_router.json BENCH_denoise.json BENCH_serve.json; do
    if [ -s "rust/$snap" ]; then
        cp "rust/$snap" "$snap"
        echo "== bench snapshot: $snap =="
        cat "$snap"
    else
        echo "ci.sh: ERROR — rust/$snap is missing or empty (bench binary stopped emitting its snapshot)" >&2
        fail=1
    fi
done

# The serve snapshot must carry the idle-fleet memory sweep (quiet
# sessions' resident bytes are the lazy-materialization regression
# canary), the wire-mode round trip (wire_to_snapshot_p99_us proves
# the TCP front door was actually exercised end to end), the chaos
# sweep (clean_session_p99_under_faults_us proves panic isolation was
# measured with faulty tenants in the fleet), AND the per-stage
# telemetry p99s (stage_* + queue_wait prove the observability plane
# was live through the whole bench) — same hard-fail policy as a
# missing snapshot.
for key in resident_bytes_per_session duty_pct wire_to_snapshot_p99_us clean_session_p99_under_faults_us \
           stage_decode_p99_us stage_score_p99_us stage_route_p99_us stage_render_p99_us queue_wait_p99_us; do
    if [ -s rust/BENCH_serve.json ] && ! grep -q "\"$key\"" rust/BENCH_serve.json; then
        echo "ci.sh: ERROR — rust/BENCH_serve.json lacks required bench key \"$key\"" >&2
        fail=1
    fi
done
exit "$fail"
