//! Repo tooling, dependency-free:
//!
//! * `cargo xtask lint-invariants [src-root]` — custom lints encoding
//!   repo law that clippy cannot see (table below).
//! * `cargo xtask bench-compare OLD.json NEW.json [tolerance-pct]` —
//!   diff two bench/obs JSON artifacts (`util::bench::dump_json` shape)
//!   and fail on a >20% (default) regression: latency/size keys
//!   (`mean_ns`, `*_us`, `*_bytes`) must not rise past tolerance,
//!   throughput keys (`meps`, `*_per_sec`) must not fall past it.
//!
//! One lint rule per invariant documented in CONTRIBUTING.md:
//!
//! | rule | invariant |
//! |---|---|
//! | `transcendental-in-hot-loop` | no `exp`/`ln`/`powf` inside `frame*` / `support_count*` functions — readout math goes through the quantized `DecayLut`, never `libm` (the PR-2 contract) |
//! | `unbounded-channel` | no unbounded queue constructors anywhere — concurrency code uses the bounded `util::sync::chan` so backpressure propagates structurally |
//! | `missing-safety-comment` | every `unsafe` carries a `// SAFETY:` comment on the same or one of the 3 preceding lines |
//! | `undocumented-pub-item` | every pub fn/struct/enum/trait/type/const/static in `serve`/`coordinator`/`denoise` has a doc comment |
//! | `unanchored-band-array` | band-scoped array construction anchors with `IscConfig::origin_y`; no raw `y - band_start` rebasing |
//! | `eager-alloc` | no full-resolution allocations (`vec!`/`Vec::with_capacity` sized by `w * h` / `width * height`) in `serve/`/`coordinator/` — band state materializes lazily on first write (PR 7); justified exceptions carry `lint-invariants: allow(eager-alloc)` |
//! | `net-deadline` | no bare `.read(`/`.read_exact(`/`.write(`/`.write_all(`/… in `serve/net/` outside `deadline.rs` — socket I/O goes through `DeadlineStream`'s configured-timeout wrappers so no handler blocks unboundedly (PR 8) |
//! | `panic-boundary` | no `unwrap`/`expect`/`panic!`/`unreachable!`/`todo!`/bare index expressions on the scheduler job path (`execute*`/`quarantine`/`export_band*`/`sync_resident` in `serve/scheduler.rs`) — a panic there is a session quarantine at best and a worker death at worst, so job bodies stay panic-free by construction; code inside a `catch_boundary(…)` wrapper is exempt (the supervision boundary contains it), as is a justified `lint-invariants: allow(panic-boundary)` (PR 9) |
//! | `telemetry-naming` | every metric name at a registration/render call site (`.counter("…")` / `.gauge("…")` / `.histogram("…")` / `push_gauge(…)` / `render_histogram(…)`) matches the name law `^[a-z0-9_]+(_total\|_us\|_bytes\|_ratio)$`, and `serve/`/`coordinator/` never `println!` — stdout is not a telemetry surface; numbers exit through the registry's scrape/export surfaces (PR 10) |
//!
//! The scanners are deliberately line-based over rustfmt-shaped source —
//! dependency-free, so the suite builds in offline containers. Each rule
//! is a pure function `(path, source) -> Vec<Violation>` (unit-tested on
//! seeded violations below); `main` only walks `rust/src` and prints.
//!
//! Suppress a finding by putting `lint-invariants: allow(<rule>)` in a
//! comment on the flagged line or the line directly above it.

use std::fmt;
use std::path::{Path, PathBuf};

/// One lint finding.
#[derive(Debug)]
struct Violation {
    file: String,
    /// 1-indexed.
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Drop the `// …` tail of a line (doc comments included). Naive on
/// purpose: no string in this codebase embeds `//`, and a false strip
/// inside a string could only hide a violation in dead text.
fn strip_comment(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

/// `lint-invariants: allow(<rule>)` on this line or the one above it.
fn suppressed(lines: &[&str], idx: usize, rule: &str) -> bool {
    let tag = format!("lint-invariants: allow({rule})");
    lines[idx].contains(&tag) || (idx > 0 && lines[idx - 1].contains(&tag))
}

/// Locate the function whose header sits on `lines[start]` and return
/// the line range of its body (header line through closing brace,
/// inclusive), or None for a bodyless declaration. Rustfmt shape
/// assumed: braces never hide inside strings on the same line as code
/// this scanner cares about.
fn fn_body_range(lines: &[&str], start: usize) -> Option<(usize, usize)> {
    let mut depth: i64 = 0;
    let mut started = false;
    for (j, raw) in lines.iter().enumerate().skip(start) {
        let code = strip_comment(raw);
        // A declaration that ends before any `{` has no body.
        if !started && code.contains(';') && !code.contains('{') {
            return None;
        }
        for c in code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if started && depth <= 0 {
            return Some((start, j));
        }
    }
    None
}

/// The name declared by `fn <name>` on this line, if any.
fn fn_name(code: &str) -> Option<&str> {
    let i = code.find("fn ")?;
    // Reject identifiers ending in `fn` (e.g. `pub fnord`): `fn` must
    // start the line or follow a non-ident character.
    if i > 0 {
        let prev = code.as_bytes()[i - 1];
        if prev != b' ' && prev != b'(' {
            return None;
        }
    }
    let rest = &code[i + 3..];
    let end = rest
        .find(|c: char| !(c.is_alphanumeric() || c == '_'))
        .unwrap_or(rest.len());
    if end == 0 {
        None
    } else {
        Some(&rest[..end])
    }
}

const TRANSCENDENTALS: &[&str] = &[".exp(", ".exp2(", ".ln(", ".ln_1p(", ".powf("];

/// DecayLut hot-loop law: `frame*` and `support_count*` functions are
/// the readout hot paths — any per-pixel transcendental there is the
/// O(H·W) `libm` cost the quantized decay LUT exists to remove.
fn check_hot_loop_transcendentals(path: &str, src: &str) -> Vec<Violation> {
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        let code = strip_comment(lines[i]);
        let hot = fn_name(code)
            .map(|n| n.starts_with("frame") || n.starts_with("support_count"))
            .unwrap_or(false);
        if !hot {
            i += 1;
            continue;
        }
        let Some((lo, hi)) = fn_body_range(&lines, i) else {
            i += 1;
            continue;
        };
        for (j, raw) in lines.iter().enumerate().take(hi + 1).skip(lo) {
            let body = strip_comment(raw);
            for tok in TRANSCENDENTALS {
                if body.contains(tok) && !suppressed(&lines, j, "transcendental-in-hot-loop") {
                    out.push(Violation {
                        file: path.to_string(),
                        line: j + 1,
                        rule: "transcendental-in-hot-loop",
                        msg: format!(
                            "`{tok}` inside hot readout fn — use the DecayLut, \
                             or hoist the call out of the per-pixel path"
                        ),
                    });
                }
            }
        }
        i = hi + 1;
    }
    out
}

const UNBOUNDED: &[&str] =
    &["std::sync::mpsc", "mpsc::channel(", "unbounded_channel", "::unbounded("];

/// Bounded-queue law: every queue in the tree is bounded so backpressure
/// propagates to producers instead of buffering a hot camera stream
/// unboundedly. `util::sync::chan` is the one sanctioned channel.
fn check_unbounded_channels(path: &str, src: &str) -> Vec<Violation> {
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        for tok in UNBOUNDED {
            if code.contains(tok) && !suppressed(&lines, i, "unbounded-channel") {
                out.push(Violation {
                    file: path.to_string(),
                    line: i + 1,
                    rule: "unbounded-channel",
                    msg: format!("`{tok}` — use the bounded `util::sync::chan` instead"),
                });
            }
        }
    }
    out
}

/// Every `unsafe` carries a `// SAFETY:` comment on the same line or
/// within the 3 preceding lines.
fn check_safety_comments(path: &str, src: &str) -> Vec<Violation> {
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        let is_unsafe = code
            .split(|c: char| !(c.is_alphanumeric() || c == '_'))
            .any(|w| w == "unsafe");
        if !is_unsafe {
            continue;
        }
        let explained =
            lines[i.saturating_sub(3)..=i].iter().any(|l| l.contains("SAFETY:"));
        if !explained && !suppressed(&lines, i, "missing-safety-comment") {
            out.push(Violation {
                file: path.to_string(),
                line: i + 1,
                rule: "missing-safety-comment",
                msg: "`unsafe` without a `// SAFETY:` comment in the 3 lines above".to_string(),
            });
        }
    }
    out
}

/// Directories whose pub API must be documented (the concurrency stack
/// users actually build against).
fn doc_scoped(path: &str) -> bool {
    ["serve/", "coordinator/", "denoise/"].iter().any(|d| path.contains(d))
}

const PUB_ITEMS: &[&str] = &[
    "pub fn ",
    "pub unsafe fn ",
    "pub struct ",
    "pub enum ",
    "pub trait ",
    "pub type ",
    "pub const ",
    "pub static ",
];

/// Every pub item in `serve`/`coordinator`/`denoise` carries a doc
/// comment (attributes may sit between the docs and the item). `pub use`
/// re-exports, `pub mod` declarations (documented by their file's `//!`
/// header), `pub(crate)` items, struct fields, and `mod tests` tails are
/// out of scope.
fn check_pub_docs(path: &str, src: &str) -> Vec<Violation> {
    if !doc_scoped(path) {
        return Vec::new();
    }
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let t = raw.trim_start();
        // Unit-test tails hold no public API.
        if t.starts_with("mod tests") && t.ends_with('{') {
            break;
        }
        if !PUB_ITEMS.iter().any(|p| t.starts_with(p)) {
            continue;
        }
        let mut j = i;
        let documented = loop {
            if j == 0 {
                break false;
            }
            j -= 1;
            let above = lines[j].trim_start();
            if above.starts_with("#[") {
                continue; // attributes sit between docs and item
            }
            break above.starts_with("///");
        };
        if !documented && !suppressed(&lines, i, "undocumented-pub-item") {
            out.push(Violation {
                file: path.to_string(),
                line: i + 1,
                rule: "undocumented-pub-item",
                msg: format!("undocumented pub item: `{}`", t.trim_end().trim_end_matches('{')),
            });
        }
    }
    out
}

/// Array constructors a band-scoped function might call.
const ARRAY_CTORS: &[&str] = &[
    "IscArray::new(",
    "Sae::new(",
    "Sae::with_recency(",
    "StcfBackend::isc(",
    "StcfBackend::ideal_with_window(",
];

/// Band-math anchoring law: a function that constructs an array AND
/// computes band row offsets (`* band_h`, `band_start`, `band_end`)
/// must anchor through `IscConfig::origin_y` — that is what makes every
/// band array an exact window of the full-sensor mismatch map, so
/// sharding can never perturb values. Raw `y - band_start` rebasing is
/// banned outright.
fn check_band_anchoring(path: &str, src: &str) -> Vec<Violation> {
    if !doc_scoped(path) {
        return Vec::new();
    }
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        if let Some(k) = code.find("- band_start") {
            // Word boundary: don't fire on e.g. `- band_starts_here`.
            let tail = &code[k + "- band_start".len()..];
            let bounded = !tail.starts_with(|c: char| c.is_alphanumeric() || c == '_');
            if bounded && !suppressed(&lines, i, "unanchored-band-array") {
                out.push(Violation {
                    file: path.to_string(),
                    line: i + 1,
                    rule: "unanchored-band-array",
                    msg: "raw `… - band_start` rebasing — anchor the array with \
                          `IscConfig::origin_y` instead"
                        .to_string(),
                });
            }
        }
    }
    let mut i = 0;
    while i < lines.len() {
        let header = strip_comment(lines[i]);
        let Some(name) = fn_name(header) else {
            i += 1;
            continue;
        };
        let Some((lo, hi)) = fn_body_range(&lines, i) else {
            i += 1;
            continue;
        };
        let body: String =
            lines[lo..=hi].iter().map(|l| strip_comment(l)).collect::<Vec<_>>().join("\n");
        let constructs = ARRAY_CTORS.iter().any(|c| body.contains(c));
        let band_offsets = body.contains("* band_h")
            || body.contains("band_start")
            || body.contains("band_end");
        if constructs
            && band_offsets
            && !body.contains("origin_y")
            && !suppressed(&lines, i, "unanchored-band-array")
        {
            out.push(Violation {
                file: path.to_string(),
                line: i + 1,
                rule: "unanchored-band-array",
                msg: format!(
                    "fn `{name}` builds an array with band row offsets but never \
                     sets `origin_y` — the band is not a window of the full-sensor map"
                ),
            });
        }
        i = hi + 1;
    }
    out
}

/// Allocation call sites the eager-alloc rule inspects.
const ALLOC_SITES: &[&str] = &["vec!", "Vec::with_capacity("];

/// Lazy-materialization law (PR 7): `serve/` and `coordinator/` hold
/// per-session state whose footprint must be activity-proportional, so
/// a `vec!` / `Vec::with_capacity` sized by the sensor resolution
/// (`w * h`, `width * height`) is an eager O(H·W) allocation that
/// bypasses lazy band materialization. Full-resolution state goes
/// through the materialization helpers (`IscArray::new` inside
/// `BandWriter::apply_batch`, render buffers via `Grid::ensure_shape`);
/// a justified exception carries `lint-invariants: allow(eager-alloc)`.
fn check_eager_alloc(path: &str, src: &str) -> Vec<Violation> {
    if !["serve/", "coordinator/"].iter().any(|d| path.contains(d)) {
        return Vec::new();
    }
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        if !ALLOC_SITES.iter().any(|s| code.contains(s)) {
            continue;
        }
        // Whitespace-normalized so rustfmt line breaks inside the size
        // expression don't matter for the single-line forms we target.
        let flat = code.split_whitespace().collect::<Vec<_>>().join(" ");
        let full_res = flat.contains("w * h")
            || flat.contains("h * w")
            || (flat.contains('*') && flat.contains("width") && flat.contains("height"));
        if full_res && !suppressed(&lines, i, "eager-alloc") {
            out.push(Violation {
                file: path.to_string(),
                line: i + 1,
                rule: "eager-alloc",
                msg: "full-resolution allocation in the session stack — materialize \
                      lazily on first write (see BandWriter) or justify with \
                      `lint-invariants: allow(eager-alloc)`"
                    .to_string(),
            });
        }
    }
    out
}

/// Raw stream calls the net-deadline rule rejects outside the wrapper.
/// Paren-inclusive on purpose: `.read_exact(` does not match the
/// sanctioned `.read_exact_within(`, and likewise for writes.
const RAW_IO_SITES: &[&str] = &[
    ".read(",
    ".read_exact(",
    ".read_to_end(",
    ".read_to_string(",
    ".write(",
    ".write_all(",
];

/// Deadline law (PR 8): every socket read/write in `serve/net/` goes
/// through `DeadlineStream`'s configured-timeout wrappers
/// (`read_exact_within` / `read_exact_polled` / `write_all_within`) so
/// no connection handler can block unboundedly on a slow or hostile
/// peer. Only `deadline.rs` itself — the wrapper — touches the raw
/// stream. A bare `.read(` / `.write_all(` / … anywhere else under
/// `serve/net/` is a slow-loris hole.
fn check_net_deadline(path: &str, src: &str) -> Vec<Violation> {
    if !path.contains("serve/net/") || path.ends_with("deadline.rs") {
        return Vec::new();
    }
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        let Some(site) = RAW_IO_SITES.iter().find(|s| code.contains(*s)) else { continue };
        if suppressed(&lines, i, "net-deadline") {
            continue;
        }
        out.push(Violation {
            file: path.to_string(),
            line: i + 1,
            rule: "net-deadline",
            msg: format!(
                "bare `{site}` in serve/net — socket I/O must go through \
                 DeadlineStream's timeout wrappers (read_exact_within / \
                 read_exact_polled / write_all_within) or move into deadline.rs"
            ),
        });
    }
    out
}

/// Panic sites the panic-boundary rule bans on the job path.
const PANIC_SITES: &[&str] = &[".unwrap(", ".expect(", "panic!(", "unreachable!(", "todo!("];

/// Job-path function prefixes in `serve/scheduler.rs`: everything a
/// worker thread runs between dequeue and reply.
const JOB_PATH_FNS: &[&str] = &["execute", "quarantine", "export_band", "sync_resident"];

/// A bare index expression (`ident[`, `)[`, `][`) on this line — the
/// implicit-panic site `.get()` exists to avoid. Macro brackets
/// (`vec![`), attribute brackets (`#[`) and type/array brackets
/// (preceded by space or `(`) do not match: the opening bracket must
/// directly follow an identifier character or a closing `)`/`]`.
fn bare_index_site(code: &str) -> bool {
    let b = code.as_bytes();
    (1..b.len()).any(|k| {
        b[k] == b'['
            && (b[k - 1].is_ascii_alphanumeric()
                || b[k - 1] == b'_'
                || b[k - 1] == b')'
                || b[k - 1] == b']')
    })
}

/// Panic-boundary law (PR 9): the scheduler job path must be panic-free
/// by construction — a panic there quarantines a session at best and
/// kills a worker at worst, so `unwrap`/`expect`/`panic!`/
/// `unreachable!`/`todo!` and bare index expressions are banned inside
/// the job-path functions of `serve/scheduler.rs`. Lines inside a
/// `catch_boundary(…)` call are exempt: that *is* the supervision
/// boundary, and a panic there is contained into a typed
/// `SessionFault`. Justified exceptions carry
/// `lint-invariants: allow(panic-boundary)`.
fn check_panic_boundary(path: &str, src: &str) -> Vec<Violation> {
    if !path.ends_with("serve/scheduler.rs") {
        return Vec::new();
    }
    let lines: Vec<&str> = src.lines().collect();

    // Lines covered by a catch_boundary(...) call, tracked by paren
    // balance from the call site to its closing parenthesis.
    let mut covered = vec![false; lines.len()];
    for i in 0..lines.len() {
        let Some(k) = strip_comment(lines[i]).find("catch_boundary(") else { continue };
        let mut depth = 0i64;
        let mut off = k;
        let mut j = i;
        'scan: while j < lines.len() {
            for c in strip_comment(lines[j])[off..].chars() {
                match c {
                    '(' => depth += 1,
                    ')' => {
                        depth -= 1;
                        if depth == 0 {
                            covered[j] = true;
                            break 'scan;
                        }
                    }
                    _ => {}
                }
            }
            covered[j] = true;
            j += 1;
            off = 0;
        }
    }

    let mut out = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        let job_fn = fn_name(strip_comment(lines[i]))
            .map(|n| JOB_PATH_FNS.iter().any(|p| n.starts_with(p)))
            .unwrap_or(false);
        if !job_fn {
            i += 1;
            continue;
        }
        let Some((lo, hi)) = fn_body_range(&lines, i) else {
            i += 1;
            continue;
        };
        for j in lo..=hi {
            if covered[j] || suppressed(&lines, j, "panic-boundary") {
                continue;
            }
            let code = strip_comment(lines[j]);
            for tok in PANIC_SITES {
                if code.contains(tok) {
                    out.push(Violation {
                        file: path.to_string(),
                        line: j + 1,
                        rule: "panic-boundary",
                        msg: format!(
                            "`{tok}` on the scheduler job path — job bodies are \
                             panic-free by construction (quarantine via typed \
                             faults); wrap in catch_boundary or justify with \
                             `lint-invariants: allow(panic-boundary)`"
                        ),
                    });
                }
            }
            if bare_index_site(code) {
                out.push(Violation {
                    file: path.to_string(),
                    line: j + 1,
                    rule: "panic-boundary",
                    msg: "bare index expression on the scheduler job path — use \
                          `.get(..)` and quarantine on miss instead of panicking"
                        .to_string(),
                });
            }
        }
        i = hi + 1;
    }
    out
}

/// The metric-name law (PR 10), duplicated from `util::telemetry` so
/// the linter stays dependency-free: lowercase snake_case with a
/// unit/kind suffix, `^[a-z0-9_]+(_total|_us|_bytes|_ratio)$`.
fn valid_metric_name(name: &str) -> bool {
    let chars_ok =
        name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_');
    let suffix_ok = ["_total", "_us", "_bytes", "_ratio"]
        .iter()
        .any(|s| name.len() > s.len() && name.ends_with(s));
    chars_ok && suffix_ok
}

/// Call sites whose first string-literal argument is a metric name.
const METRIC_NAME_SITES: &[&str] =
    &[".counter(", ".gauge(", ".histogram(", "push_gauge(", "render_histogram("];

/// The first `"…"` string literal after byte offset `from`, if any
/// (metric names never embed quotes or escapes).
fn first_str_literal(code: &str, from: usize) -> Option<&str> {
    let rest = &code[from..];
    let a = rest.find('"')?;
    let b = rest[a + 1..].find('"')?;
    Some(&rest[a + 1..a + 1 + b])
}

/// Telemetry-naming law (PR 10): every metric name handed to a registry
/// registration or render helper matches the name law, so one scrape is
/// uniformly machine-parseable; and `serve/`/`coordinator/` never write
/// to stdout directly — a number worth printing is a metric, and
/// metrics exit through the scrape/export surfaces. `eprintln!` stays
/// legal (operator diagnostics, not a telemetry surface). Dynamic names
/// (no literal on the line) are out of scope — the registry's
/// debug_assert covers them at runtime.
fn check_telemetry_naming(path: &str, src: &str) -> Vec<Violation> {
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        for site in METRIC_NAME_SITES {
            let Some(k) = code.find(site) else { continue };
            let Some(name) = first_str_literal(code, k + site.len()) else { continue };
            if !valid_metric_name(name) && !suppressed(&lines, i, "telemetry-naming") {
                out.push(Violation {
                    file: path.to_string(),
                    line: i + 1,
                    rule: "telemetry-naming",
                    msg: format!(
                        "metric name `{name}` breaks the name law \
                         `^[a-z0-9_]+(_total|_us|_bytes|_ratio)$` — lowercase \
                         snake_case with a unit/kind suffix"
                    ),
                });
            }
        }
        if ["serve/", "coordinator/"].iter().any(|d| path.contains(d)) {
            if let Some(k) = code.find("println!") {
                let b = code.as_bytes();
                let bare = k == 0 || !(b[k - 1].is_ascii_alphanumeric() || b[k - 1] == b'_');
                if bare && !suppressed(&lines, i, "telemetry-naming") {
                    out.push(Violation {
                        file: path.to_string(),
                        line: i + 1,
                        rule: "telemetry-naming",
                        msg: "bare `println!` in the session stack — stdout is not a \
                              telemetry surface; register a metric (util::telemetry) \
                              or use eprintln! for operator diagnostics"
                            .to_string(),
                    });
                }
            }
        }
    }
    out
}

/// Run every rule over one file.
fn check_file(path: &str, src: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    out.extend(check_hot_loop_transcendentals(path, src));
    out.extend(check_unbounded_channels(path, src));
    out.extend(check_safety_comments(path, src));
    out.extend(check_pub_docs(path, src));
    out.extend(check_band_anchoring(path, src));
    out.extend(check_eager_alloc(path, src));
    out.extend(check_net_deadline(path, src));
    out.extend(check_panic_boundary(path, src));
    out.extend(check_telemetry_naming(path, src));
    out
}

/// All `.rs` files under `dir`, sorted for deterministic output.
fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rust_files(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// The crate source root: `<workspace>/rust/src`, found relative to this
/// crate's manifest so the lint runs from any working directory.
fn default_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../rust/src")
}

fn run_lints(root: &Path) -> Result<Vec<Violation>, String> {
    let mut files = Vec::new();
    rust_files(root, &mut files).map_err(|e| format!("walking {}: {e}", root.display()))?;
    if files.is_empty() {
        return Err(format!("no .rs files under {}", root.display()));
    }
    let mut all = Vec::new();
    for f in &files {
        let src =
            std::fs::read_to_string(f).map_err(|e| format!("reading {}: {e}", f.display()))?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .into_owned();
        all.extend(check_file(&rel, &src));
    }
    Ok(all)
}

/// One row of a bench JSON artifact (`util::bench::dump_json` shape):
/// the benchmark name plus every numeric field.
#[derive(Debug)]
struct BenchRow {
    name: String,
    values: Vec<(String, f64)>,
}

/// Parse a `{"benchmarks": [...]}` artifact without a JSON dependency.
/// The shape is fixed (`dump_json` writes it, this tool diffs it), so
/// the parser handles exactly that: one flat object per benchmark,
/// string or numeric values, no nesting, no escaped quotes.
fn parse_bench_json(src: &str) -> Result<Vec<BenchRow>, String> {
    let start = src
        .find("\"benchmarks\"")
        .ok_or_else(|| "missing \"benchmarks\" key".to_string())?;
    let rest = &src[start..];
    let mut rows = Vec::new();
    let mut depth = 0i64;
    let mut obj_start = None;
    for (i, c) in rest.char_indices() {
        match c {
            '{' => {
                depth += 1;
                if depth == 1 {
                    obj_start = Some(i);
                }
            }
            '}' => {
                if depth == 1 {
                    let s = obj_start.take().ok_or("unbalanced benchmark object")?;
                    rows.push(parse_bench_obj(&rest[s..=i])?);
                }
                depth -= 1;
            }
            ']' if depth == 0 => break,
            _ => {}
        }
    }
    Ok(rows)
}

/// Parse one flat `{"key": value, ...}` benchmark object.
fn parse_bench_obj(obj: &str) -> Result<BenchRow, String> {
    let mut name = None;
    let mut values = Vec::new();
    let b = obj.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if b[i] != b'"' {
            i += 1;
            continue;
        }
        let kend = obj[i + 1..]
            .find('"')
            .map(|k| i + 1 + k)
            .ok_or("unterminated key string")?;
        let key = obj[i + 1..kend].to_string();
        i = kend + 1;
        while i < b.len() && b[i] != b':' {
            i += 1;
        }
        i += 1;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        if i < b.len() && b[i] == b'"' {
            let vend = obj[i + 1..]
                .find('"')
                .map(|k| i + 1 + k)
                .ok_or("unterminated value string")?;
            if key == "name" {
                name = Some(obj[i + 1..vend].to_string());
            }
            i = vend + 1;
        } else {
            let vstart = i;
            while i < b.len() && b[i] != b',' && b[i] != b'}' {
                i += 1;
            }
            if let Ok(v) = obj[vstart..i].trim().parse::<f64>() {
                values.push((key, v));
            }
        }
    }
    let name = name.ok_or_else(|| format!("benchmark object without a \"name\": {obj}"))?;
    Ok(BenchRow { name, values })
}

/// Regression direction for a bench key: `Some(true)` = higher is
/// worse (latency, size), `Some(false)` = lower is worse (throughput),
/// `None` = informational only (iteration counts, knobs, noise terms).
fn higher_is_worse(key: &str) -> Option<bool> {
    match key {
        "mean_ns" | "min_ns" => Some(true),
        "meps" => Some(false),
        "iters" | "stddev_ns" | "items_per_iter" => None,
        k if k.ends_with("_ns") || k.ends_with("_us") || k.ends_with("_bytes") => Some(true),
        k if k.ends_with("_per_sec") || k.ends_with("_meps") => Some(false),
        _ => None,
    }
}

/// Diff two parsed bench artifacts: one report line per compared key,
/// plus the subset that regressed past `tol` (fractional, e.g. `0.20`).
/// Benchmarks present on only one side are reported but never fail —
/// the suite is allowed to grow and shrink; the gate is on drift.
fn bench_compare(old: &[BenchRow], new: &[BenchRow], tol: f64) -> (Vec<String>, Vec<String>) {
    let mut report = Vec::new();
    let mut regressions = Vec::new();
    for n in new {
        let Some(o) = old.iter().find(|r| r.name == n.name) else {
            report.push(format!("{}: new benchmark (no baseline)", n.name));
            continue;
        };
        for (key, nv) in &n.values {
            let Some(worse_if_higher) = higher_is_worse(key) else { continue };
            let Some((_, ov)) = o.values.iter().find(|(k, _)| k == key) else { continue };
            // A zero/negative baseline has no scale to regress against.
            if *ov <= 0.0 {
                continue;
            }
            let ratio = nv / ov;
            let regressed =
                if worse_if_higher { ratio > 1.0 + tol } else { ratio < 1.0 - tol };
            let line = format!(
                "{} {}: {:.3} -> {:.3} ({:+.1}%)",
                n.name,
                key,
                ov,
                nv,
                (ratio - 1.0) * 100.0
            );
            if regressed {
                regressions.push(line.clone());
            }
            report.push(line);
        }
    }
    for o in old {
        if !new.iter().any(|r| r.name == o.name) {
            report.push(format!("{}: benchmark missing from new run", o.name));
        }
    }
    (report, regressions)
}

fn run_bench_compare(old_path: &str, new_path: &str, tol_pct: f64) -> Result<bool, String> {
    let read = |p: &str| {
        std::fs::read_to_string(p).map_err(|e| format!("reading {p}: {e}"))
    };
    let old = parse_bench_json(&read(old_path)?)
        .map_err(|e| format!("parsing {old_path}: {e}"))?;
    let new = parse_bench_json(&read(new_path)?)
        .map_err(|e| format!("parsing {new_path}: {e}"))?;
    let (report, regressions) = bench_compare(&old, &new, tol_pct / 100.0);
    for line in &report {
        println!("{line}");
    }
    if regressions.is_empty() {
        println!("bench-compare: OK ({} line(s) within {tol_pct}%)", report.len());
        Ok(true)
    } else {
        eprintln!("bench-compare: {} regression(s) past {tol_pct}%:", regressions.len());
        for r in &regressions {
            eprintln!("  {r}");
        }
        Ok(false)
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("bench-compare") => {
            let (Some(old_path), Some(new_path)) = (args.get(1), args.get(2)) else {
                eprintln!("usage: cargo xtask bench-compare OLD.json NEW.json [tolerance-pct]");
                std::process::exit(2);
            };
            let tol_pct = match args.get(3) {
                Some(s) => match s.parse::<f64>() {
                    Ok(v) if v > 0.0 => v,
                    _ => {
                        eprintln!("bench-compare: bad tolerance `{s}` (want a positive %)");
                        std::process::exit(2);
                    }
                },
                None => 20.0,
            };
            match run_bench_compare(old_path, new_path, tol_pct) {
                Ok(true) => {}
                Ok(false) => std::process::exit(1),
                Err(e) => {
                    eprintln!("bench-compare: {e}");
                    std::process::exit(2);
                }
            }
        }
        Some("lint-invariants") => {
            let root = args.get(1).map(PathBuf::from).unwrap_or_else(default_root);
            match run_lints(&root) {
                Ok(v) if v.is_empty() => {
                    println!("lint-invariants: OK ({})", root.display());
                }
                Ok(v) => {
                    for violation in &v {
                        eprintln!("{violation}");
                    }
                    eprintln!("lint-invariants: {} violation(s)", v.len());
                    std::process::exit(1);
                }
                Err(e) => {
                    eprintln!("lint-invariants: {e}");
                    std::process::exit(2);
                }
            }
        }
        _ => {
            eprintln!(
                "usage: cargo xtask <lint-invariants [src-root] | \
                 bench-compare OLD.json NEW.json [tolerance-pct]>"
            );
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- transcendental-in-hot-loop ----

    #[test]
    fn catches_exp_in_frame_fn() {
        let src = "
fn frame_merged_into(out: &mut [f64], dt: f64) {
    for v in out.iter_mut() {
        *v = (-dt).exp();
    }
}
";
        let v = check_hot_loop_transcendentals("isc/mod.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "transcendental-in-hot-loop");
        assert_eq!(v[0].line, 4);
    }

    #[test]
    fn catches_powf_in_support_count() {
        let src = "
pub fn support_count_fast(x: f64) -> u32 {
    (x.powf(2.0)) as u32
}
";
        assert_eq!(check_hot_loop_transcendentals("denoise/stcf.rs", src).len(), 1);
    }

    #[test]
    fn cold_fns_may_use_transcendentals() {
        // The LUT builder itself computes exp() once per level — legal.
        let src = "
fn build_lut(tau: f64) -> Vec<f64> {
    (0..64).map(|k| (-(k as f64) / tau).exp()).collect()
}
";
        assert!(check_hot_loop_transcendentals("util/decay.rs", src).is_empty());
    }

    #[test]
    fn hot_loop_suppression_comment_works() {
        let src = "
fn frame_debug_dump(x: f64) -> f64 {
    // lint-invariants: allow(transcendental-in-hot-loop)
    x.exp()
}
";
        assert!(check_hot_loop_transcendentals("util/image.rs", src).is_empty());
    }

    // ---- unbounded-channel ----

    #[test]
    fn catches_std_mpsc_channel() {
        let src = "let (tx, rx) = std::sync::mpsc::channel::<u32>();\n";
        let v = check_unbounded_channels("coordinator/router.rs", src);
        // `std::sync::mpsc` and `mpsc::channel(` both match the line.
        assert!(!v.is_empty());
        assert!(v.iter().all(|x| x.rule == "unbounded-channel"));
    }

    #[test]
    fn mentions_in_comments_are_fine() {
        let src = "// semantically a subset of std::sync::mpsc::sync_channel\n";
        assert!(check_unbounded_channels("util/sync.rs", src).is_empty());
    }

    #[test]
    fn bounded_chan_is_fine() {
        let src = "let (tx, rx) = crate::util::sync::chan::bounded::<Job>(2);\n";
        assert!(check_unbounded_channels("denoise/sharded.rs", src).is_empty());
    }

    // ---- missing-safety-comment ----

    #[test]
    fn catches_unsafe_without_safety() {
        let src = "
fn peel(xs: &mut [u8]) -> &mut [u8] {
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr(), xs.len()) }
}
";
        let v = check_safety_comments("util/grid.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "missing-safety-comment");
    }

    #[test]
    fn safety_comment_satisfies_the_rule() {
        let src = "
fn peel(xs: &mut [u8]) -> &mut [u8] {
    // SAFETY: same slice, same provenance, same length.
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr(), xs.len()) }
}
";
        assert!(check_safety_comments("util/grid.rs", src).is_empty());
    }

    #[test]
    fn unsafe_in_identifier_is_not_flagged() {
        let src = "let unsafety_counter = 0;\n";
        assert!(check_safety_comments("util/grid.rs", src).is_empty());
    }

    // ---- undocumented-pub-item ----

    #[test]
    fn catches_undocumented_pub_fn_in_serve() {
        let src = "
impl Pool {
    pub fn workers(&self) -> usize {
        self.n
    }
}
";
        let v = check_pub_docs("serve/scheduler.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "undocumented-pub-item");
    }

    #[test]
    fn docs_plus_attributes_are_accepted() {
        let src = "
/// The fixed worker fleet.
#[derive(Debug)]
pub struct Pool {
    n: usize,
}
";
        assert!(check_pub_docs("serve/scheduler.rs", src).is_empty());
    }

    #[test]
    fn pub_crate_and_other_dirs_are_out_of_scope() {
        let src = "
pub(crate) fn internal() {}
";
        assert!(check_pub_docs("serve/scheduler.rs", src).is_empty());
        let undocumented = "
pub fn helper() {}
";
        assert!(check_pub_docs("util/stats.rs", undocumented).is_empty());
    }

    #[test]
    fn test_module_tail_is_skipped() {
        let src = "
/// Documented.
pub fn fine() {}

#[cfg(test)]
mod tests {
    pub fn helper_without_docs() {}
}
";
        assert!(check_pub_docs("denoise/sharded.rs", src).is_empty());
    }

    // ---- unanchored-band-array ----

    #[test]
    fn catches_band_ctor_without_origin() {
        let src = "
fn for_band(res: Resolution, band_h: usize, shard: usize) -> IscArray {
    let y0 = shard * band_h;
    let rows = band_h.min(res.height as usize - y0);
    IscArray::new(Resolution::new(res.width, rows as u16), cfg.clone())
}
";
        let v = check_band_anchoring("coordinator/router.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "unanchored-band-array");
    }

    #[test]
    fn origin_anchored_band_ctor_is_fine() {
        let src = "
fn for_band(res: Resolution, band_h: usize, shard: usize) -> IscArray {
    let y0 = (shard * band_h) as u16;
    let mut cfg = base.clone();
    cfg.origin_y = base.origin_y + y0;
    IscArray::new(band_res, cfg)
}
";
        assert!(check_band_anchoring("coordinator/router.rs", src).is_empty());
    }

    #[test]
    fn full_sensor_ctor_without_band_math_is_fine() {
        let src = "
fn isc(res: Resolution, cfg: IscConfig) -> StcfBackend {
    StcfBackend::Isc(IscArray::new(res, cfg))
}
";
        assert!(check_band_anchoring("denoise/stcf.rs", src).is_empty());
    }

    #[test]
    fn catches_raw_band_start_rebasing() {
        let src = "let yl = e.y as usize - band_start;\n";
        let v = check_band_anchoring("denoise/sharded.rs", src);
        assert_eq!(v.len(), 1);
    }

    // ---- eager-alloc ----

    #[test]
    fn catches_full_resolution_vec_in_serve() {
        let src = "
fn open_session(res: Resolution) -> Vec<f64> {
    vec![0.0; res.width as usize * res.height as usize]
}
";
        let v = check_eager_alloc("serve/session.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "eager-alloc");
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn catches_with_capacity_w_times_h_in_coordinator() {
        let src = "let buf: Vec<f64> = Vec::with_capacity(w * h);\n";
        assert_eq!(check_eager_alloc("coordinator/router.rs", src).len(), 1);
    }

    #[test]
    fn batch_sized_allocs_are_fine() {
        let src = "
fn staging(batch_size: usize, n_bands: usize) -> Vec<Vec<Event>> {
    let mut v = Vec::with_capacity(n_bands);
    v.push(Vec::with_capacity(batch_size));
    v
}
";
        assert!(check_eager_alloc("coordinator/router.rs", src).is_empty());
    }

    #[test]
    fn eager_alloc_scope_and_suppression() {
        // Outside serve/ and coordinator/ the rule does not apply (the
        // dense backends legitimately allocate O(H·W) surfaces).
        let src = "let t = vec![0u64; width * height];\n";
        assert!(check_eager_alloc("tsurface/sae.rs", src).is_empty());
        // Inside, a justified exception is suppressible.
        let allowed = "
// lint-invariants: allow(eager-alloc)
let composite = vec![0.0; res.width as usize * res.height as usize];
";
        assert!(check_eager_alloc("serve/session.rs", allowed).is_empty());
    }

    // ---- net-deadline ----

    #[test]
    fn catches_bare_reads_and_writes_in_serve_net() {
        let src = "
fn pump(stream: &mut TcpStream, buf: &mut [u8]) {
    stream.read_exact(buf).unwrap();
    stream.write_all(buf).unwrap();
    let _ = stream.read(buf);
}
";
        let v = check_net_deadline("serve/net/conn.rs", src);
        assert_eq!(v.len(), 3, "{v:?}");
        assert!(v.iter().all(|x| x.rule == "net-deadline"));
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn timeout_wrappers_do_not_trip_net_deadline() {
        // The sanctioned calls share prefixes with the banned tokens —
        // the paren-inclusive match must not confuse them.
        let src = "
fn pump(dl: &mut DeadlineStream, buf: &mut [u8]) -> io::Result<()> {
    dl.read_exact_within(buf, TIMEOUT)?;
    dl.read_exact_polled(buf, TIMEOUT, TICK, || false)?;
    dl.write_all_within(buf)
}
";
        assert!(check_net_deadline("serve/net/conn.rs", src).is_empty());
    }

    #[test]
    fn net_deadline_scope_and_suppression() {
        let src = "let n = stream.read(&mut buf)?;\n";
        // deadline.rs is the wrapper — the one place raw I/O is legal.
        assert!(check_net_deadline("serve/net/deadline.rs", src).is_empty());
        // Outside serve/net/ the rule does not apply.
        assert!(check_net_deadline("serve/session.rs", src).is_empty());
        assert!(check_net_deadline("events/aer.rs", src).is_empty());
        // Inside, a justified exception is suppressible.
        let allowed = "
// lint-invariants: allow(net-deadline)
let n = stream.read(&mut buf)?;
";
        assert!(check_net_deadline("serve/net/server.rs", allowed).is_empty());
    }

    // ---- panic-boundary ----

    #[test]
    fn catches_unwrap_and_panic_in_job_body() {
        let src = "
fn execute_inner(job: Job, slot: &mut BandSlot) {
    let v = slot.state.take().unwrap();
    panic!(\"boom\");
}
";
        let v = check_panic_boundary("serve/scheduler.rs", src);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().all(|x| x.rule == "panic-boundary"));
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn catches_bare_indexing_but_not_macros_or_attributes() {
        let src = "
fn execute(job: Job, slot: &mut BandSlot) {
    let x = slot.bands[3];
}
";
        assert_eq!(check_panic_boundary("serve/scheduler.rs", src).len(), 1);
        let fine = "
fn execute(job: Job, slot: &mut BandSlot) {
    #[allow(dead_code)]
    let v = vec![0u8; 4];
    let y = slot.bands.get(3);
}
";
        assert!(check_panic_boundary("serve/scheduler.rs", fine).is_empty(), "macro/attr brackets");
    }

    #[test]
    fn catch_boundary_wrapped_code_is_exempt() {
        let src = "
fn execute_inner(job: Job, slot: &mut BandSlot) {
    if let Err(msg) = catch_boundary(|| {
        let v = items[0];
        w.apply_batch(&mut batch).expect(\"apply\");
    }) {
        failed = Some(msg);
    }
}
";
        assert!(check_panic_boundary("serve/scheduler.rs", src).is_empty());
    }

    #[test]
    fn panic_boundary_scope_and_suppression() {
        // Producer-side functions in scheduler.rs are out of scope —
        // expects with context are legal off the worker path.
        let src = "
fn spawn_actor(&self, seed: BandSeed) -> Arc<BandActor> {
    self.inner.lock().expect(\"pool lock\").spawn()
}
";
        assert!(check_panic_boundary("serve/scheduler.rs", src).is_empty());
        // Other files are out of scope entirely.
        let job = "
fn execute(job: Job) {
    job.reply.send(0).unwrap();
}
";
        assert!(check_panic_boundary("serve/session.rs", job).is_empty());
        // Inside, a justified exception is suppressible.
        let allowed = "
fn execute(job: Job, slot: &mut BandSlot) {
    // lint-invariants: allow(panic-boundary)
    let v = slot.state.take().unwrap();
}
";
        assert!(check_panic_boundary("serve/scheduler.rs", allowed).is_empty());
    }

    // ---- telemetry-naming ----

    #[test]
    fn catches_bad_metric_names_at_registration() {
        let src = "
let c = reg.counter(\"badName\");
let h = reg.histogram(\"queue_wait\");
";
        let v = check_telemetry_naming("serve/obs.rs", src);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().all(|x| x.rule == "telemetry-naming"));
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn lawful_and_dynamic_metric_names_pass() {
        let src = "
let c = reg.counter(\"events_in_total\");
let h = registry.histogram(\"stage_route_us\");
push_gauge(&mut out, \"resident_bytes\", v);
render_histogram(&mut out, \"session_queue_wait_us\", &labels, &h);
let dynamic = reg.counter(name);
";
        assert!(check_telemetry_naming("serve/obs.rs", src).is_empty());
    }

    #[test]
    fn catches_bare_println_in_session_stack() {
        let src = "println!(\"jobs: {}\", n);\n";
        assert_eq!(check_telemetry_naming("serve/session.rs", src).len(), 1);
        assert_eq!(check_telemetry_naming("coordinator/pipeline.rs", src).len(), 1);
        // eprintln! is operator diagnostics, not a telemetry surface.
        assert!(check_telemetry_naming("serve/session.rs", "eprintln!(\"x\");\n").is_empty());
        // Outside the session stack stdout is fine (bench harness, CLI).
        assert!(check_telemetry_naming("util/bench.rs", src).is_empty());
    }

    #[test]
    fn telemetry_naming_suppression_works() {
        let allowed = "
// lint-invariants: allow(telemetry-naming)
let c = reg.counter(\"WeirdLegacyName\");
";
        assert!(check_telemetry_naming("serve/obs.rs", allowed).is_empty());
    }

    // ---- bench-compare ----

    const OLD_JSON: &str = r#"{
  "benchmarks": [
    {"name": "serve_fleet", "mean_ns": 1000.0, "meps": 8.0, "queue_wait_p99_us": 50.0, "iters": 10},
    {"name": "wire", "mean_ns": 2000.0, "meps": 4.0}
  ]
}"#;

    #[test]
    fn parses_the_dump_json_shape() {
        let rows = parse_bench_json(OLD_JSON).expect("parse");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].name, "serve_fleet");
        assert!(rows[0]
            .values
            .iter()
            .any(|(k, v)| k == "queue_wait_p99_us" && *v == 50.0));
        assert!(rows[1].values.iter().any(|(k, v)| k == "meps" && *v == 4.0));
    }

    #[test]
    fn flags_latency_and_throughput_regressions() {
        let new = r#"{"benchmarks": [
  {"name": "serve_fleet", "mean_ns": 1300.0, "meps": 8.1, "queue_wait_p99_us": 49.0, "iters": 10},
  {"name": "wire", "mean_ns": 2100.0, "meps": 3.0}
]}"#;
        let (report, regressions) = bench_compare(
            &parse_bench_json(OLD_JSON).unwrap(),
            &parse_bench_json(new).unwrap(),
            0.20,
        );
        // mean_ns 1000→1300 (+30%) and meps 4.0→3.0 (−25%) regress;
        // everything else sits inside the 20% band.
        assert_eq!(regressions.len(), 2, "{regressions:?}");
        assert!(regressions[0].contains("serve_fleet mean_ns"));
        assert!(regressions[1].contains("wire meps"));
        assert!(report.len() >= regressions.len());
    }

    #[test]
    fn within_tolerance_informational_and_missing_rows_pass() {
        let new = r#"{"benchmarks": [
  {"name": "serve_fleet", "mean_ns": 1100.0, "meps": 7.0, "queue_wait_p99_us": 55.0, "iters": 99999}
]}"#;
        let (report, regressions) = bench_compare(
            &parse_bench_json(OLD_JSON).unwrap(),
            &parse_bench_json(new).unwrap(),
            0.20,
        );
        assert!(regressions.is_empty(), "{regressions:?}");
        // A benchmark dropped from the new run is reported, not failed.
        assert!(report.iter().any(|l| l.contains("wire: benchmark missing")));
    }

    // ---- whole-tree gate ----

    #[test]
    fn tree_is_clean() {
        let root = default_root();
        let v = run_lints(&root).expect("lint run");
        assert!(
            v.is_empty(),
            "invariant violations in the tree:\n{}",
            v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
