//! `cargo xtask lint-invariants` — custom lints encoding repo law that
//! clippy cannot see. One rule per invariant documented in
//! CONTRIBUTING.md:
//!
//! | rule | invariant |
//! |---|---|
//! | `transcendental-in-hot-loop` | no `exp`/`ln`/`powf` inside `frame*` / `support_count*` functions — readout math goes through the quantized `DecayLut`, never `libm` (the PR-2 contract) |
//! | `unbounded-channel` | no unbounded queue constructors anywhere — concurrency code uses the bounded `util::sync::chan` so backpressure propagates structurally |
//! | `missing-safety-comment` | every `unsafe` carries a `// SAFETY:` comment on the same or one of the 3 preceding lines |
//! | `undocumented-pub-item` | every pub fn/struct/enum/trait/type/const/static in `serve`/`coordinator`/`denoise` has a doc comment |
//! | `unanchored-band-array` | band-scoped array construction anchors with `IscConfig::origin_y`; no raw `y - band_start` rebasing |
//! | `eager-alloc` | no full-resolution allocations (`vec!`/`Vec::with_capacity` sized by `w * h` / `width * height`) in `serve/`/`coordinator/` — band state materializes lazily on first write (PR 7); justified exceptions carry `lint-invariants: allow(eager-alloc)` |
//! | `net-deadline` | no bare `.read(`/`.read_exact(`/`.write(`/`.write_all(`/… in `serve/net/` outside `deadline.rs` — socket I/O goes through `DeadlineStream`'s configured-timeout wrappers so no handler blocks unboundedly (PR 8) |
//! | `panic-boundary` | no `unwrap`/`expect`/`panic!`/`unreachable!`/`todo!`/bare index expressions on the scheduler job path (`execute*`/`quarantine`/`export_band*`/`sync_resident` in `serve/scheduler.rs`) — a panic there is a session quarantine at best and a worker death at worst, so job bodies stay panic-free by construction; code inside a `catch_boundary(…)` wrapper is exempt (the supervision boundary contains it), as is a justified `lint-invariants: allow(panic-boundary)` (PR 9) |
//!
//! The scanners are deliberately line-based over rustfmt-shaped source —
//! dependency-free, so the suite builds in offline containers. Each rule
//! is a pure function `(path, source) -> Vec<Violation>` (unit-tested on
//! seeded violations below); `main` only walks `rust/src` and prints.
//!
//! Suppress a finding by putting `lint-invariants: allow(<rule>)` in a
//! comment on the flagged line or the line directly above it.

use std::fmt;
use std::path::{Path, PathBuf};

/// One lint finding.
#[derive(Debug)]
struct Violation {
    file: String,
    /// 1-indexed.
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Drop the `// …` tail of a line (doc comments included). Naive on
/// purpose: no string in this codebase embeds `//`, and a false strip
/// inside a string could only hide a violation in dead text.
fn strip_comment(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

/// `lint-invariants: allow(<rule>)` on this line or the one above it.
fn suppressed(lines: &[&str], idx: usize, rule: &str) -> bool {
    let tag = format!("lint-invariants: allow({rule})");
    lines[idx].contains(&tag) || (idx > 0 && lines[idx - 1].contains(&tag))
}

/// Locate the function whose header sits on `lines[start]` and return
/// the line range of its body (header line through closing brace,
/// inclusive), or None for a bodyless declaration. Rustfmt shape
/// assumed: braces never hide inside strings on the same line as code
/// this scanner cares about.
fn fn_body_range(lines: &[&str], start: usize) -> Option<(usize, usize)> {
    let mut depth: i64 = 0;
    let mut started = false;
    for (j, raw) in lines.iter().enumerate().skip(start) {
        let code = strip_comment(raw);
        // A declaration that ends before any `{` has no body.
        if !started && code.contains(';') && !code.contains('{') {
            return None;
        }
        for c in code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if started && depth <= 0 {
            return Some((start, j));
        }
    }
    None
}

/// The name declared by `fn <name>` on this line, if any.
fn fn_name(code: &str) -> Option<&str> {
    let i = code.find("fn ")?;
    // Reject identifiers ending in `fn` (e.g. `pub fnord`): `fn` must
    // start the line or follow a non-ident character.
    if i > 0 {
        let prev = code.as_bytes()[i - 1];
        if prev != b' ' && prev != b'(' {
            return None;
        }
    }
    let rest = &code[i + 3..];
    let end = rest
        .find(|c: char| !(c.is_alphanumeric() || c == '_'))
        .unwrap_or(rest.len());
    if end == 0 {
        None
    } else {
        Some(&rest[..end])
    }
}

const TRANSCENDENTALS: &[&str] = &[".exp(", ".exp2(", ".ln(", ".ln_1p(", ".powf("];

/// DecayLut hot-loop law: `frame*` and `support_count*` functions are
/// the readout hot paths — any per-pixel transcendental there is the
/// O(H·W) `libm` cost the quantized decay LUT exists to remove.
fn check_hot_loop_transcendentals(path: &str, src: &str) -> Vec<Violation> {
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        let code = strip_comment(lines[i]);
        let hot = fn_name(code)
            .map(|n| n.starts_with("frame") || n.starts_with("support_count"))
            .unwrap_or(false);
        if !hot {
            i += 1;
            continue;
        }
        let Some((lo, hi)) = fn_body_range(&lines, i) else {
            i += 1;
            continue;
        };
        for (j, raw) in lines.iter().enumerate().take(hi + 1).skip(lo) {
            let body = strip_comment(raw);
            for tok in TRANSCENDENTALS {
                if body.contains(tok) && !suppressed(&lines, j, "transcendental-in-hot-loop") {
                    out.push(Violation {
                        file: path.to_string(),
                        line: j + 1,
                        rule: "transcendental-in-hot-loop",
                        msg: format!(
                            "`{tok}` inside hot readout fn — use the DecayLut, \
                             or hoist the call out of the per-pixel path"
                        ),
                    });
                }
            }
        }
        i = hi + 1;
    }
    out
}

const UNBOUNDED: &[&str] =
    &["std::sync::mpsc", "mpsc::channel(", "unbounded_channel", "::unbounded("];

/// Bounded-queue law: every queue in the tree is bounded so backpressure
/// propagates to producers instead of buffering a hot camera stream
/// unboundedly. `util::sync::chan` is the one sanctioned channel.
fn check_unbounded_channels(path: &str, src: &str) -> Vec<Violation> {
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        for tok in UNBOUNDED {
            if code.contains(tok) && !suppressed(&lines, i, "unbounded-channel") {
                out.push(Violation {
                    file: path.to_string(),
                    line: i + 1,
                    rule: "unbounded-channel",
                    msg: format!("`{tok}` — use the bounded `util::sync::chan` instead"),
                });
            }
        }
    }
    out
}

/// Every `unsafe` carries a `// SAFETY:` comment on the same line or
/// within the 3 preceding lines.
fn check_safety_comments(path: &str, src: &str) -> Vec<Violation> {
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        let is_unsafe = code
            .split(|c: char| !(c.is_alphanumeric() || c == '_'))
            .any(|w| w == "unsafe");
        if !is_unsafe {
            continue;
        }
        let explained =
            lines[i.saturating_sub(3)..=i].iter().any(|l| l.contains("SAFETY:"));
        if !explained && !suppressed(&lines, i, "missing-safety-comment") {
            out.push(Violation {
                file: path.to_string(),
                line: i + 1,
                rule: "missing-safety-comment",
                msg: "`unsafe` without a `// SAFETY:` comment in the 3 lines above".to_string(),
            });
        }
    }
    out
}

/// Directories whose pub API must be documented (the concurrency stack
/// users actually build against).
fn doc_scoped(path: &str) -> bool {
    ["serve/", "coordinator/", "denoise/"].iter().any(|d| path.contains(d))
}

const PUB_ITEMS: &[&str] = &[
    "pub fn ",
    "pub unsafe fn ",
    "pub struct ",
    "pub enum ",
    "pub trait ",
    "pub type ",
    "pub const ",
    "pub static ",
];

/// Every pub item in `serve`/`coordinator`/`denoise` carries a doc
/// comment (attributes may sit between the docs and the item). `pub use`
/// re-exports, `pub mod` declarations (documented by their file's `//!`
/// header), `pub(crate)` items, struct fields, and `mod tests` tails are
/// out of scope.
fn check_pub_docs(path: &str, src: &str) -> Vec<Violation> {
    if !doc_scoped(path) {
        return Vec::new();
    }
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let t = raw.trim_start();
        // Unit-test tails hold no public API.
        if t.starts_with("mod tests") && t.ends_with('{') {
            break;
        }
        if !PUB_ITEMS.iter().any(|p| t.starts_with(p)) {
            continue;
        }
        let mut j = i;
        let documented = loop {
            if j == 0 {
                break false;
            }
            j -= 1;
            let above = lines[j].trim_start();
            if above.starts_with("#[") {
                continue; // attributes sit between docs and item
            }
            break above.starts_with("///");
        };
        if !documented && !suppressed(&lines, i, "undocumented-pub-item") {
            out.push(Violation {
                file: path.to_string(),
                line: i + 1,
                rule: "undocumented-pub-item",
                msg: format!("undocumented pub item: `{}`", t.trim_end().trim_end_matches('{')),
            });
        }
    }
    out
}

/// Array constructors a band-scoped function might call.
const ARRAY_CTORS: &[&str] = &[
    "IscArray::new(",
    "Sae::new(",
    "Sae::with_recency(",
    "StcfBackend::isc(",
    "StcfBackend::ideal_with_window(",
];

/// Band-math anchoring law: a function that constructs an array AND
/// computes band row offsets (`* band_h`, `band_start`, `band_end`)
/// must anchor through `IscConfig::origin_y` — that is what makes every
/// band array an exact window of the full-sensor mismatch map, so
/// sharding can never perturb values. Raw `y - band_start` rebasing is
/// banned outright.
fn check_band_anchoring(path: &str, src: &str) -> Vec<Violation> {
    if !doc_scoped(path) {
        return Vec::new();
    }
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        if let Some(k) = code.find("- band_start") {
            // Word boundary: don't fire on e.g. `- band_starts_here`.
            let tail = &code[k + "- band_start".len()..];
            let bounded = !tail.starts_with(|c: char| c.is_alphanumeric() || c == '_');
            if bounded && !suppressed(&lines, i, "unanchored-band-array") {
                out.push(Violation {
                    file: path.to_string(),
                    line: i + 1,
                    rule: "unanchored-band-array",
                    msg: "raw `… - band_start` rebasing — anchor the array with \
                          `IscConfig::origin_y` instead"
                        .to_string(),
                });
            }
        }
    }
    let mut i = 0;
    while i < lines.len() {
        let header = strip_comment(lines[i]);
        let Some(name) = fn_name(header) else {
            i += 1;
            continue;
        };
        let Some((lo, hi)) = fn_body_range(&lines, i) else {
            i += 1;
            continue;
        };
        let body: String =
            lines[lo..=hi].iter().map(|l| strip_comment(l)).collect::<Vec<_>>().join("\n");
        let constructs = ARRAY_CTORS.iter().any(|c| body.contains(c));
        let band_offsets = body.contains("* band_h")
            || body.contains("band_start")
            || body.contains("band_end");
        if constructs
            && band_offsets
            && !body.contains("origin_y")
            && !suppressed(&lines, i, "unanchored-band-array")
        {
            out.push(Violation {
                file: path.to_string(),
                line: i + 1,
                rule: "unanchored-band-array",
                msg: format!(
                    "fn `{name}` builds an array with band row offsets but never \
                     sets `origin_y` — the band is not a window of the full-sensor map"
                ),
            });
        }
        i = hi + 1;
    }
    out
}

/// Allocation call sites the eager-alloc rule inspects.
const ALLOC_SITES: &[&str] = &["vec!", "Vec::with_capacity("];

/// Lazy-materialization law (PR 7): `serve/` and `coordinator/` hold
/// per-session state whose footprint must be activity-proportional, so
/// a `vec!` / `Vec::with_capacity` sized by the sensor resolution
/// (`w * h`, `width * height`) is an eager O(H·W) allocation that
/// bypasses lazy band materialization. Full-resolution state goes
/// through the materialization helpers (`IscArray::new` inside
/// `BandWriter::apply_batch`, render buffers via `Grid::ensure_shape`);
/// a justified exception carries `lint-invariants: allow(eager-alloc)`.
fn check_eager_alloc(path: &str, src: &str) -> Vec<Violation> {
    if !["serve/", "coordinator/"].iter().any(|d| path.contains(d)) {
        return Vec::new();
    }
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        if !ALLOC_SITES.iter().any(|s| code.contains(s)) {
            continue;
        }
        // Whitespace-normalized so rustfmt line breaks inside the size
        // expression don't matter for the single-line forms we target.
        let flat = code.split_whitespace().collect::<Vec<_>>().join(" ");
        let full_res = flat.contains("w * h")
            || flat.contains("h * w")
            || (flat.contains('*') && flat.contains("width") && flat.contains("height"));
        if full_res && !suppressed(&lines, i, "eager-alloc") {
            out.push(Violation {
                file: path.to_string(),
                line: i + 1,
                rule: "eager-alloc",
                msg: "full-resolution allocation in the session stack — materialize \
                      lazily on first write (see BandWriter) or justify with \
                      `lint-invariants: allow(eager-alloc)`"
                    .to_string(),
            });
        }
    }
    out
}

/// Raw stream calls the net-deadline rule rejects outside the wrapper.
/// Paren-inclusive on purpose: `.read_exact(` does not match the
/// sanctioned `.read_exact_within(`, and likewise for writes.
const RAW_IO_SITES: &[&str] = &[
    ".read(",
    ".read_exact(",
    ".read_to_end(",
    ".read_to_string(",
    ".write(",
    ".write_all(",
];

/// Deadline law (PR 8): every socket read/write in `serve/net/` goes
/// through `DeadlineStream`'s configured-timeout wrappers
/// (`read_exact_within` / `read_exact_polled` / `write_all_within`) so
/// no connection handler can block unboundedly on a slow or hostile
/// peer. Only `deadline.rs` itself — the wrapper — touches the raw
/// stream. A bare `.read(` / `.write_all(` / … anywhere else under
/// `serve/net/` is a slow-loris hole.
fn check_net_deadline(path: &str, src: &str) -> Vec<Violation> {
    if !path.contains("serve/net/") || path.ends_with("deadline.rs") {
        return Vec::new();
    }
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        let Some(site) = RAW_IO_SITES.iter().find(|s| code.contains(*s)) else { continue };
        if suppressed(&lines, i, "net-deadline") {
            continue;
        }
        out.push(Violation {
            file: path.to_string(),
            line: i + 1,
            rule: "net-deadline",
            msg: format!(
                "bare `{site}` in serve/net — socket I/O must go through \
                 DeadlineStream's timeout wrappers (read_exact_within / \
                 read_exact_polled / write_all_within) or move into deadline.rs"
            ),
        });
    }
    out
}

/// Panic sites the panic-boundary rule bans on the job path.
const PANIC_SITES: &[&str] = &[".unwrap(", ".expect(", "panic!(", "unreachable!(", "todo!("];

/// Job-path function prefixes in `serve/scheduler.rs`: everything a
/// worker thread runs between dequeue and reply.
const JOB_PATH_FNS: &[&str] = &["execute", "quarantine", "export_band", "sync_resident"];

/// A bare index expression (`ident[`, `)[`, `][`) on this line — the
/// implicit-panic site `.get()` exists to avoid. Macro brackets
/// (`vec![`), attribute brackets (`#[`) and type/array brackets
/// (preceded by space or `(`) do not match: the opening bracket must
/// directly follow an identifier character or a closing `)`/`]`.
fn bare_index_site(code: &str) -> bool {
    let b = code.as_bytes();
    (1..b.len()).any(|k| {
        b[k] == b'['
            && (b[k - 1].is_ascii_alphanumeric()
                || b[k - 1] == b'_'
                || b[k - 1] == b')'
                || b[k - 1] == b']')
    })
}

/// Panic-boundary law (PR 9): the scheduler job path must be panic-free
/// by construction — a panic there quarantines a session at best and
/// kills a worker at worst, so `unwrap`/`expect`/`panic!`/
/// `unreachable!`/`todo!` and bare index expressions are banned inside
/// the job-path functions of `serve/scheduler.rs`. Lines inside a
/// `catch_boundary(…)` call are exempt: that *is* the supervision
/// boundary, and a panic there is contained into a typed
/// `SessionFault`. Justified exceptions carry
/// `lint-invariants: allow(panic-boundary)`.
fn check_panic_boundary(path: &str, src: &str) -> Vec<Violation> {
    if !path.ends_with("serve/scheduler.rs") {
        return Vec::new();
    }
    let lines: Vec<&str> = src.lines().collect();

    // Lines covered by a catch_boundary(...) call, tracked by paren
    // balance from the call site to its closing parenthesis.
    let mut covered = vec![false; lines.len()];
    for i in 0..lines.len() {
        let Some(k) = strip_comment(lines[i]).find("catch_boundary(") else { continue };
        let mut depth = 0i64;
        let mut off = k;
        let mut j = i;
        'scan: while j < lines.len() {
            for c in strip_comment(lines[j])[off..].chars() {
                match c {
                    '(' => depth += 1,
                    ')' => {
                        depth -= 1;
                        if depth == 0 {
                            covered[j] = true;
                            break 'scan;
                        }
                    }
                    _ => {}
                }
            }
            covered[j] = true;
            j += 1;
            off = 0;
        }
    }

    let mut out = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        let job_fn = fn_name(strip_comment(lines[i]))
            .map(|n| JOB_PATH_FNS.iter().any(|p| n.starts_with(p)))
            .unwrap_or(false);
        if !job_fn {
            i += 1;
            continue;
        }
        let Some((lo, hi)) = fn_body_range(&lines, i) else {
            i += 1;
            continue;
        };
        for j in lo..=hi {
            if covered[j] || suppressed(&lines, j, "panic-boundary") {
                continue;
            }
            let code = strip_comment(lines[j]);
            for tok in PANIC_SITES {
                if code.contains(tok) {
                    out.push(Violation {
                        file: path.to_string(),
                        line: j + 1,
                        rule: "panic-boundary",
                        msg: format!(
                            "`{tok}` on the scheduler job path — job bodies are \
                             panic-free by construction (quarantine via typed \
                             faults); wrap in catch_boundary or justify with \
                             `lint-invariants: allow(panic-boundary)`"
                        ),
                    });
                }
            }
            if bare_index_site(code) {
                out.push(Violation {
                    file: path.to_string(),
                    line: j + 1,
                    rule: "panic-boundary",
                    msg: "bare index expression on the scheduler job path — use \
                          `.get(..)` and quarantine on miss instead of panicking"
                        .to_string(),
                });
            }
        }
        i = hi + 1;
    }
    out
}

/// Run every rule over one file.
fn check_file(path: &str, src: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    out.extend(check_hot_loop_transcendentals(path, src));
    out.extend(check_unbounded_channels(path, src));
    out.extend(check_safety_comments(path, src));
    out.extend(check_pub_docs(path, src));
    out.extend(check_band_anchoring(path, src));
    out.extend(check_eager_alloc(path, src));
    out.extend(check_net_deadline(path, src));
    out.extend(check_panic_boundary(path, src));
    out
}

/// All `.rs` files under `dir`, sorted for deterministic output.
fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rust_files(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// The crate source root: `<workspace>/rust/src`, found relative to this
/// crate's manifest so the lint runs from any working directory.
fn default_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../rust/src")
}

fn run_lints(root: &Path) -> Result<Vec<Violation>, String> {
    let mut files = Vec::new();
    rust_files(root, &mut files).map_err(|e| format!("walking {}: {e}", root.display()))?;
    if files.is_empty() {
        return Err(format!("no .rs files under {}", root.display()));
    }
    let mut all = Vec::new();
    for f in &files {
        let src =
            std::fs::read_to_string(f).map_err(|e| format!("reading {}: {e}", f.display()))?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .into_owned();
        all.extend(check_file(&rel, &src));
    }
    Ok(all)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint-invariants") => {
            let root = args.get(1).map(PathBuf::from).unwrap_or_else(default_root);
            match run_lints(&root) {
                Ok(v) if v.is_empty() => {
                    println!("lint-invariants: OK ({})", root.display());
                }
                Ok(v) => {
                    for violation in &v {
                        eprintln!("{violation}");
                    }
                    eprintln!("lint-invariants: {} violation(s)", v.len());
                    std::process::exit(1);
                }
                Err(e) => {
                    eprintln!("lint-invariants: {e}");
                    std::process::exit(2);
                }
            }
        }
        _ => {
            eprintln!("usage: cargo xtask lint-invariants [src-root]");
            std::process::exit(2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- transcendental-in-hot-loop ----

    #[test]
    fn catches_exp_in_frame_fn() {
        let src = "
fn frame_merged_into(out: &mut [f64], dt: f64) {
    for v in out.iter_mut() {
        *v = (-dt).exp();
    }
}
";
        let v = check_hot_loop_transcendentals("isc/mod.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "transcendental-in-hot-loop");
        assert_eq!(v[0].line, 4);
    }

    #[test]
    fn catches_powf_in_support_count() {
        let src = "
pub fn support_count_fast(x: f64) -> u32 {
    (x.powf(2.0)) as u32
}
";
        assert_eq!(check_hot_loop_transcendentals("denoise/stcf.rs", src).len(), 1);
    }

    #[test]
    fn cold_fns_may_use_transcendentals() {
        // The LUT builder itself computes exp() once per level — legal.
        let src = "
fn build_lut(tau: f64) -> Vec<f64> {
    (0..64).map(|k| (-(k as f64) / tau).exp()).collect()
}
";
        assert!(check_hot_loop_transcendentals("util/decay.rs", src).is_empty());
    }

    #[test]
    fn hot_loop_suppression_comment_works() {
        let src = "
fn frame_debug_dump(x: f64) -> f64 {
    // lint-invariants: allow(transcendental-in-hot-loop)
    x.exp()
}
";
        assert!(check_hot_loop_transcendentals("util/image.rs", src).is_empty());
    }

    // ---- unbounded-channel ----

    #[test]
    fn catches_std_mpsc_channel() {
        let src = "let (tx, rx) = std::sync::mpsc::channel::<u32>();\n";
        let v = check_unbounded_channels("coordinator/router.rs", src);
        // `std::sync::mpsc` and `mpsc::channel(` both match the line.
        assert!(!v.is_empty());
        assert!(v.iter().all(|x| x.rule == "unbounded-channel"));
    }

    #[test]
    fn mentions_in_comments_are_fine() {
        let src = "// semantically a subset of std::sync::mpsc::sync_channel\n";
        assert!(check_unbounded_channels("util/sync.rs", src).is_empty());
    }

    #[test]
    fn bounded_chan_is_fine() {
        let src = "let (tx, rx) = crate::util::sync::chan::bounded::<Job>(2);\n";
        assert!(check_unbounded_channels("denoise/sharded.rs", src).is_empty());
    }

    // ---- missing-safety-comment ----

    #[test]
    fn catches_unsafe_without_safety() {
        let src = "
fn peel(xs: &mut [u8]) -> &mut [u8] {
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr(), xs.len()) }
}
";
        let v = check_safety_comments("util/grid.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "missing-safety-comment");
    }

    #[test]
    fn safety_comment_satisfies_the_rule() {
        let src = "
fn peel(xs: &mut [u8]) -> &mut [u8] {
    // SAFETY: same slice, same provenance, same length.
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr(), xs.len()) }
}
";
        assert!(check_safety_comments("util/grid.rs", src).is_empty());
    }

    #[test]
    fn unsafe_in_identifier_is_not_flagged() {
        let src = "let unsafety_counter = 0;\n";
        assert!(check_safety_comments("util/grid.rs", src).is_empty());
    }

    // ---- undocumented-pub-item ----

    #[test]
    fn catches_undocumented_pub_fn_in_serve() {
        let src = "
impl Pool {
    pub fn workers(&self) -> usize {
        self.n
    }
}
";
        let v = check_pub_docs("serve/scheduler.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "undocumented-pub-item");
    }

    #[test]
    fn docs_plus_attributes_are_accepted() {
        let src = "
/// The fixed worker fleet.
#[derive(Debug)]
pub struct Pool {
    n: usize,
}
";
        assert!(check_pub_docs("serve/scheduler.rs", src).is_empty());
    }

    #[test]
    fn pub_crate_and_other_dirs_are_out_of_scope() {
        let src = "
pub(crate) fn internal() {}
";
        assert!(check_pub_docs("serve/scheduler.rs", src).is_empty());
        let undocumented = "
pub fn helper() {}
";
        assert!(check_pub_docs("util/stats.rs", undocumented).is_empty());
    }

    #[test]
    fn test_module_tail_is_skipped() {
        let src = "
/// Documented.
pub fn fine() {}

#[cfg(test)]
mod tests {
    pub fn helper_without_docs() {}
}
";
        assert!(check_pub_docs("denoise/sharded.rs", src).is_empty());
    }

    // ---- unanchored-band-array ----

    #[test]
    fn catches_band_ctor_without_origin() {
        let src = "
fn for_band(res: Resolution, band_h: usize, shard: usize) -> IscArray {
    let y0 = shard * band_h;
    let rows = band_h.min(res.height as usize - y0);
    IscArray::new(Resolution::new(res.width, rows as u16), cfg.clone())
}
";
        let v = check_band_anchoring("coordinator/router.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "unanchored-band-array");
    }

    #[test]
    fn origin_anchored_band_ctor_is_fine() {
        let src = "
fn for_band(res: Resolution, band_h: usize, shard: usize) -> IscArray {
    let y0 = (shard * band_h) as u16;
    let mut cfg = base.clone();
    cfg.origin_y = base.origin_y + y0;
    IscArray::new(band_res, cfg)
}
";
        assert!(check_band_anchoring("coordinator/router.rs", src).is_empty());
    }

    #[test]
    fn full_sensor_ctor_without_band_math_is_fine() {
        let src = "
fn isc(res: Resolution, cfg: IscConfig) -> StcfBackend {
    StcfBackend::Isc(IscArray::new(res, cfg))
}
";
        assert!(check_band_anchoring("denoise/stcf.rs", src).is_empty());
    }

    #[test]
    fn catches_raw_band_start_rebasing() {
        let src = "let yl = e.y as usize - band_start;\n";
        let v = check_band_anchoring("denoise/sharded.rs", src);
        assert_eq!(v.len(), 1);
    }

    // ---- eager-alloc ----

    #[test]
    fn catches_full_resolution_vec_in_serve() {
        let src = "
fn open_session(res: Resolution) -> Vec<f64> {
    vec![0.0; res.width as usize * res.height as usize]
}
";
        let v = check_eager_alloc("serve/session.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "eager-alloc");
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn catches_with_capacity_w_times_h_in_coordinator() {
        let src = "let buf: Vec<f64> = Vec::with_capacity(w * h);\n";
        assert_eq!(check_eager_alloc("coordinator/router.rs", src).len(), 1);
    }

    #[test]
    fn batch_sized_allocs_are_fine() {
        let src = "
fn staging(batch_size: usize, n_bands: usize) -> Vec<Vec<Event>> {
    let mut v = Vec::with_capacity(n_bands);
    v.push(Vec::with_capacity(batch_size));
    v
}
";
        assert!(check_eager_alloc("coordinator/router.rs", src).is_empty());
    }

    #[test]
    fn eager_alloc_scope_and_suppression() {
        // Outside serve/ and coordinator/ the rule does not apply (the
        // dense backends legitimately allocate O(H·W) surfaces).
        let src = "let t = vec![0u64; width * height];\n";
        assert!(check_eager_alloc("tsurface/sae.rs", src).is_empty());
        // Inside, a justified exception is suppressible.
        let allowed = "
// lint-invariants: allow(eager-alloc)
let composite = vec![0.0; res.width as usize * res.height as usize];
";
        assert!(check_eager_alloc("serve/session.rs", allowed).is_empty());
    }

    // ---- net-deadline ----

    #[test]
    fn catches_bare_reads_and_writes_in_serve_net() {
        let src = "
fn pump(stream: &mut TcpStream, buf: &mut [u8]) {
    stream.read_exact(buf).unwrap();
    stream.write_all(buf).unwrap();
    let _ = stream.read(buf);
}
";
        let v = check_net_deadline("serve/net/conn.rs", src);
        assert_eq!(v.len(), 3, "{v:?}");
        assert!(v.iter().all(|x| x.rule == "net-deadline"));
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn timeout_wrappers_do_not_trip_net_deadline() {
        // The sanctioned calls share prefixes with the banned tokens —
        // the paren-inclusive match must not confuse them.
        let src = "
fn pump(dl: &mut DeadlineStream, buf: &mut [u8]) -> io::Result<()> {
    dl.read_exact_within(buf, TIMEOUT)?;
    dl.read_exact_polled(buf, TIMEOUT, TICK, || false)?;
    dl.write_all_within(buf)
}
";
        assert!(check_net_deadline("serve/net/conn.rs", src).is_empty());
    }

    #[test]
    fn net_deadline_scope_and_suppression() {
        let src = "let n = stream.read(&mut buf)?;\n";
        // deadline.rs is the wrapper — the one place raw I/O is legal.
        assert!(check_net_deadline("serve/net/deadline.rs", src).is_empty());
        // Outside serve/net/ the rule does not apply.
        assert!(check_net_deadline("serve/session.rs", src).is_empty());
        assert!(check_net_deadline("events/aer.rs", src).is_empty());
        // Inside, a justified exception is suppressible.
        let allowed = "
// lint-invariants: allow(net-deadline)
let n = stream.read(&mut buf)?;
";
        assert!(check_net_deadline("serve/net/server.rs", allowed).is_empty());
    }

    // ---- panic-boundary ----

    #[test]
    fn catches_unwrap_and_panic_in_job_body() {
        let src = "
fn execute_inner(job: Job, slot: &mut BandSlot) {
    let v = slot.state.take().unwrap();
    panic!(\"boom\");
}
";
        let v = check_panic_boundary("serve/scheduler.rs", src);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().all(|x| x.rule == "panic-boundary"));
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn catches_bare_indexing_but_not_macros_or_attributes() {
        let src = "
fn execute(job: Job, slot: &mut BandSlot) {
    let x = slot.bands[3];
}
";
        assert_eq!(check_panic_boundary("serve/scheduler.rs", src).len(), 1);
        let fine = "
fn execute(job: Job, slot: &mut BandSlot) {
    #[allow(dead_code)]
    let v = vec![0u8; 4];
    let y = slot.bands.get(3);
}
";
        assert!(check_panic_boundary("serve/scheduler.rs", fine).is_empty(), "macro/attr brackets");
    }

    #[test]
    fn catch_boundary_wrapped_code_is_exempt() {
        let src = "
fn execute_inner(job: Job, slot: &mut BandSlot) {
    if let Err(msg) = catch_boundary(|| {
        let v = items[0];
        w.apply_batch(&mut batch).expect(\"apply\");
    }) {
        failed = Some(msg);
    }
}
";
        assert!(check_panic_boundary("serve/scheduler.rs", src).is_empty());
    }

    #[test]
    fn panic_boundary_scope_and_suppression() {
        // Producer-side functions in scheduler.rs are out of scope —
        // expects with context are legal off the worker path.
        let src = "
fn spawn_actor(&self, seed: BandSeed) -> Arc<BandActor> {
    self.inner.lock().expect(\"pool lock\").spawn()
}
";
        assert!(check_panic_boundary("serve/scheduler.rs", src).is_empty());
        // Other files are out of scope entirely.
        let job = "
fn execute(job: Job) {
    job.reply.send(0).unwrap();
}
";
        assert!(check_panic_boundary("serve/session.rs", job).is_empty());
        // Inside, a justified exception is suppressible.
        let allowed = "
fn execute(job: Job, slot: &mut BandSlot) {
    // lint-invariants: allow(panic-boundary)
    let v = slot.state.take().unwrap();
}
";
        assert!(check_panic_boundary("serve/scheduler.rs", allowed).is_empty());
    }

    // ---- whole-tree gate ----

    #[test]
    fn tree_is_clean() {
        let root = default_root();
        let v = run_lints(&root).expect("lint run");
        assert!(
            v.is_empty(),
            "invariant violations in the tree:\n{}",
            v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
