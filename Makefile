# Build / test / bench entry points. `make ci` is the tier-1 gate plus a
# quick bench snapshot (BENCH_tsurface.json) so every PR leaves a perf
# trajectory behind.

RUST_DIR := rust
PYTHON := python3

.PHONY: ci build test bench lint artifacts clean

ci:
	./ci.sh

build:
	cd $(RUST_DIR) && cargo build --release

test:
	cd $(RUST_DIR) && cargo test -q

# Style gate: formatting + clippy with warnings denied (mirrored by the
# `lint` job in .github/workflows/ci.yml and invoked from ci.sh).
lint:
	cd $(RUST_DIR) && cargo fmt --check
	cd $(RUST_DIR) && cargo clippy --all-targets -- -D warnings

# Bench binaries use the in-repo harness (util::bench); bench_tsurface,
# bench_router, bench_denoise and bench_serve additionally dump
# BENCH_tsurface.json / BENCH_router.json / BENCH_denoise.json /
# BENCH_serve.json next to the manifest.
bench:
	cd $(RUST_DIR) && cargo bench -- --quick
	@for snap in BENCH_tsurface.json BENCH_router.json BENCH_denoise.json \
	             BENCH_serve.json; do \
		if [ -f $(RUST_DIR)/$$snap ]; then \
			cp $(RUST_DIR)/$$snap $$snap; \
			echo "snapshot: $$snap"; \
		fi; \
	done

# AOT-lower the JAX/Pallas kernels + models to HLO text artifacts for the
# Rust PJRT runtime (no-op for pure-Rust development; the runtime tests
# skip gracefully when artifacts are absent).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(RUST_DIR)/artifacts

clean:
	cd $(RUST_DIR) && cargo clean
	rm -f BENCH_tsurface.json $(RUST_DIR)/BENCH_tsurface.json \
	      BENCH_router.json $(RUST_DIR)/BENCH_router.json \
	      BENCH_denoise.json $(RUST_DIR)/BENCH_denoise.json \
	      BENCH_serve.json $(RUST_DIR)/BENCH_serve.json
