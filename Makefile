# Build / test / bench entry points. `make ci` is the tier-1 gate plus a
# quick bench snapshot (BENCH_*.json) so every PR leaves a perf
# trajectory behind. The deeper correctness gates — loom model checking,
# Miri, ThreadSanitizer, the custom invariant lints — have their own
# targets below and run as separate CI jobs.

RUST_DIR := rust
PYTHON := python3

.PHONY: ci build test bench lint lint-invariants loom miri tsan artifacts clean

ci:
	./ci.sh

build:
	cargo build --release --workspace

test:
	cargo test -q --workspace

# Style gate: formatting + clippy with warnings denied (mirrored by the
# `lint` job in .github/workflows/ci.yml and invoked from ci.sh).
lint:
	cargo fmt --all --check
	cargo clippy --workspace --all-targets -- -D warnings

# Repo-specific invariants clippy cannot see (DecayLut hot-loop law,
# bounded channels, SAFETY comments, pub docs in the concurrency stack,
# origin_y band anchoring, no eager full-resolution allocations in
# serve/coordinator). See CONTRIBUTING.md and xtask/src/main.rs.
lint-invariants:
	cargo xtask lint-invariants

# Exhaustive interleaving checks for the scheduler core and the bounded
# channel (rust/tests/loom_sched.rs). loom is a cfg-gated dependency:
# plain builds never compile it.
loom:
	cd $(RUST_DIR) && RUSTFLAGS="--cfg loom" cargo test --release --test loom_sched

# Miri over the code that owns the crate's only unsafe block
# (Grid::row_slabs_mut) and its scoped-thread consumers. Needs nightly:
# rustup +nightly component add miri.
miri:
	cd $(RUST_DIR) && cargo +nightly miri test --lib util::grid util::parallel

# ThreadSanitizer over the cross-thread equivalence suites (serve fleet
# vs dedicated pipeline, sharded STCF vs sequential). Needs nightly and
# a std built for the sanitizer (-Zbuild-std).
tsan:
	cd $(RUST_DIR) && RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
		cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
		--release --test serve_equiv --test stcf_equiv

# AOT-lower the JAX/Pallas kernels + models to HLO text artifacts for the
# Rust PJRT runtime (no-op for pure-Rust development; the runtime tests
# skip gracefully when artifacts are absent).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(RUST_DIR)/artifacts

# Quick bench snapshots. BENCH_serve.json includes the idle-fleet
# memory sweep (256 sessions at 1/10/100 % duty cycle:
# resident_bytes_per_session + events_per_sec) that ci.sh hard-requires.
bench:
	cd $(RUST_DIR) && cargo bench -- --quick
	@for snap in BENCH_tsurface.json BENCH_router.json BENCH_denoise.json \
	             BENCH_serve.json; do \
		if [ -f $(RUST_DIR)/$$snap ]; then \
			cp $(RUST_DIR)/$$snap $$snap; \
			echo "snapshot: $$snap"; \
		fi; \
	done

clean:
	cargo clean
	rm -f BENCH_tsurface.json $(RUST_DIR)/BENCH_tsurface.json \
	      BENCH_router.json $(RUST_DIR)/BENCH_router.json \
	      BENCH_denoise.json $(RUST_DIR)/BENCH_denoise.json \
	      BENCH_serve.json $(RUST_DIR)/BENCH_serve.json
