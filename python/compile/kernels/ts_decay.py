"""Pallas kernels for the time-surface state update (L1).

Hardware mapping (DESIGN.md §Hardware-Adaptation): the eDRAM plane maps to
VMEM tiles — a (bh, bw) tile *is* an eDRAM subarray resident in VMEM. The
decay is a pure VPU elementwise pass over the tile; the event write is a
masked select, which is the faithful analog of the paper's per-pixel Cu-Cu
write (no row/column addressing, hence no half-select). All kernels run
with interpret=True on CPU (real-TPU lowering emits Mosaic custom-calls the
CPU PJRT plugin cannot execute; see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile shape: 256×256 f32 = 256 KiB per plane; with 8 operand planes the
# working set is ~2 MiB — comfortably VMEM-resident on any TPU generation.
BLOCK_H = 256
BLOCK_W = 256


def _ts_update_kernel(v1_ref, v2_ref, mask_ref, a1_ref, a2_ref, d1_ref, d2_ref,
                      o1_ref, o2_ref):
    """Elementwise: o_i = where(mask, A_i, v_i * d_i) for both components."""
    mask = mask_ref[...]
    o1_ref[...] = jnp.where(mask, a1_ref[...], v1_ref[...] * d1_ref[...])
    o2_ref[...] = jnp.where(mask, a2_ref[...], v2_ref[...] * d2_ref[...])


def _grid_spec(shape):
    h, w = shape
    bh, bw = min(BLOCK_H, h), min(BLOCK_W, w)
    if h % bh or w % bw:
        # Fall back to a single whole-array block for ragged sizes: at the
        # QVGA scales used here that is still well within VMEM.
        bh, bw = h, w
    grid = (h // bh, w // bw)
    spec = pl.BlockSpec((bh, bw), lambda i, j: (i, j))
    return grid, spec


@functools.partial(jax.jit, static_argnames=())
def ts_update(v1, v2, mask, a1, a2, tau1, tau2, dt):
    """Pallas time-surface update; see `ref.ts_update_ref` for semantics.

    The exp(-dt/τ) factors are computed outside the kernel (they fuse into
    the surrounding HLO); the kernel itself is the masked multiply-select
    over VMEM tiles.
    """
    d1 = jnp.exp(-dt / tau1).astype(jnp.float32)
    d2 = jnp.exp(-dt / tau2).astype(jnp.float32)
    grid, spec = _grid_spec(v1.shape)
    out_shape = [
        jax.ShapeDtypeStruct(v1.shape, jnp.float32),
        jax.ShapeDtypeStruct(v2.shape, jnp.float32),
    ]
    return pl.pallas_call(
        _ts_update_kernel,
        grid=grid,
        in_specs=[spec] * 7,
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=True,
    )(v1, v2, mask, a1, a2, d1, d2)


def _frame_kernel(v1_ref, v2_ref, o_ref, *, inv_vdd):
    """Readout: normalized [0,1] frame from the component planes."""
    o_ref[...] = jnp.clip((v1_ref[...] + v2_ref[...]) * inv_vdd, 0.0, 1.0)


@functools.partial(jax.jit, static_argnames=("vdd",))
def ts_frame(v1, v2, vdd=1.2):
    """Pallas frame readout; see `ref.ts_frame_ref`."""
    grid, spec = _grid_spec(v1.shape)
    return pl.pallas_call(
        functools.partial(_frame_kernel, inv_vdd=1.0 / vdd),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(v1.shape, jnp.float32),
        interpret=True,
    )(v1, v2)
