"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact jnp twin here; pytest
(`python/tests/test_kernels.py`) sweeps shapes/dtypes with hypothesis and
asserts allclose between the two. The oracles are also what the L2 model
uses under `use_pallas=False` for A/B fusion testing.
"""

from __future__ import annotations

import jax.numpy as jnp


def ts_update_ref(v1, v2, mask, a1, a2, tau1, tau2, dt):
    """Double-exponential time-surface state update.

    The analog cell's double-exp decay is memoryless in the 2-component
    state (v1, v2): each component decays with its own time constant and an
    event write resets the components to their fitted amplitudes (A1, A2).
    The observable surface is v1 + v2.

    Args:
      v1, v2: (H, W) f32 component planes.
      mask:   (H, W) bool - pixels written by events in this microbatch.
      a1, a2: (H, W) f32 per-pixel fitted amplitudes (mismatch map).
      tau1, tau2: (H, W) f32 per-pixel time constants, seconds.
      dt: scalar f32 - elapsed time since the previous update, seconds.

    Returns:
      (v1', v2') updated planes.
    """
    d1 = jnp.exp(-dt / tau1)
    d2 = jnp.exp(-dt / tau2)
    v1n = jnp.where(mask, a1, v1 * d1)
    v2n = jnp.where(mask, a2, v2 * d2)
    return v1n, v2n


def patch_count_ref(v, v_tw, radius):
    """STCF support count: per pixel, the number of cells in the
    (2r+1)^2 patch (center excluded) whose surface value is >= v_tw.

    Args:
      v: (H, W) f32 surface (v1 + v2).
      v_tw: scalar comparator threshold (volts).
      radius: static int patch radius.

    Returns:
      (H, W) f32 counts.
    """
    hot = (v >= v_tw).astype(jnp.float32)
    padded = jnp.pad(hot, radius, mode="constant")
    h, w = v.shape
    total = jnp.zeros_like(v)
    for dy in range(2 * radius + 1):
        for dx in range(2 * radius + 1):
            if dy == radius and dx == radius:
                continue
            total = total + padded[dy : dy + h, dx : dx + w]
    return total


def ts_frame_ref(v1, v2, vdd):
    """Readout: normalized [0,1] time-surface frame from component planes."""
    return jnp.clip((v1 + v2) / vdd, 0.0, 1.0)
