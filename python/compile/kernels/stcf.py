"""Pallas kernel for the STCF support count (L1).

Stencil mapping: the (2r+1)^2 patch count is a classic halo pattern. The L2
wrapper pads the comparator bitmap by `radius`; the kernel receives a
(bh + 2r, bw + 2r) haloed tile and accumulates the (2r+1)^2 static shifts
on the VPU. On real TPU the halo tile sits in VMEM and the shifts are
cheap lane rotations; on CPU we run interpret=True.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _patch_count_kernel(hot_ref, o_ref, *, radius, bh, bw):
    """Accumulate the (2r+1)^2 - 1 shifted views of the haloed hot map."""
    hot = hot_ref[...]  # (bh + 2r, bw + 2r)
    acc = jnp.zeros((bh, bw), jnp.float32)
    for dy in range(2 * radius + 1):
        for dx in range(2 * radius + 1):
            if dy == radius and dx == radius:
                continue
            acc = acc + jax.lax.dynamic_slice(hot, (dy, dx), (bh, bw))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("radius",))
def patch_count(v, v_tw, radius=3):
    """Pallas STCF support count; see `ref.patch_count_ref`.

    Single whole-array block with an explicit halo pad: at QVGA the haloed
    bitmap is (246, 326) f32 ≈ 314 KiB — VMEM-resident. For larger arrays
    the natural extension is a row-block grid with overlapping halo
    BlockSpecs; evaluation resolutions here do not need it.
    """
    h, w = v.shape
    hot = (v >= v_tw).astype(jnp.float32)
    padded = jnp.pad(hot, radius, mode="constant")
    kernel = functools.partial(_patch_count_kernel, radius=radius, bh=h, bw=w)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(padded)
