"""L2: JAX compute graphs — the time-surface pipeline (calling the L1
Pallas kernels), an inception-lite CNN classifier (the GoogLeNet stand-in
of Sec. IV-D) and a UNet-lite reconstruction model (Sec. IV-E), each with
full fwd/bwd train steps.

Everything here is build-time only: `aot.py` lowers these functions once to
HLO text and the Rust coordinator executes the artifacts via PJRT. Params
travel as *ordered flat lists* of arrays; the order is defined by the
`*_param_shapes()` functions and mirrored on the Rust side.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import stcf as stcf_kernel
from compile.kernels import ts_decay as ts_kernel
from compile.kernels import ref

VDD = 1.2

# ---------------------------------------------------------------------
# Time-surface pipeline (L1 kernels composed at L2)
# ---------------------------------------------------------------------


def ts_update(v1, v2, mask, a1, a2, tau1, tau2, dt, use_pallas=True):
    """One microbatch step of the analog-plane state (see kernels/ref.py)."""
    if use_pallas:
        return ts_kernel.ts_update(v1, v2, mask, a1, a2, tau1, tau2, dt)
    return ref.ts_update_ref(v1, v2, mask, a1, a2, tau1, tau2, dt)


def ts_frame(v1, v2, use_pallas=True):
    """Normalized [0,1] readout frame."""
    if use_pallas:
        return ts_kernel.ts_frame(v1, v2, VDD)
    return ref.ts_frame_ref(v1, v2, VDD)


def stcf_count(v, v_tw, radius=3, use_pallas=True):
    """STCF support-count map over the surface."""
    if use_pallas:
        return stcf_kernel.patch_count(v, v_tw, radius)
    return ref.patch_count_ref(v, v_tw, radius)


# ---------------------------------------------------------------------
# Shared NN building blocks (NCHW)
# ---------------------------------------------------------------------


def _conv(x, w, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def _maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, k, k), "VALID"
    )


def _upsample2(x):
    """Nearest-neighbour 2x upsample."""
    n, c, h, w = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :, None], (n, c, h, 2, w, 2))
    return x.reshape(n, c, 2 * h, 2 * w)


def _relu(x):
    return jnp.maximum(x, 0.0)


def _he(key, shape):
    fan_in = shape[1] * shape[2] * shape[3] if len(shape) == 4 else shape[0]
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


# ---------------------------------------------------------------------
# Inception-lite classifier (GoogLeNet stand-in), input (B, 1, 32, 32)
# ---------------------------------------------------------------------

N_CLASSES = 10
CLS_INPUT = 32


def _inception_shapes(cin, c1, c3r, c3, c5r, c5, cp):
    """Parameter shapes of one inception block (conv W + bias pairs)."""
    return [
        ((c1, cin, 1, 1), (c1,)),          # branch 1: 1x1
        ((c3r, cin, 1, 1), (c3r,)),        # branch 2: 1x1 reduce
        ((c3, c3r, 3, 3), (c3,)),          #           3x3
        ((c5r, cin, 1, 1), (c5r,)),        # branch 3: 1x1 reduce
        ((c5, c5r, 5, 5), (c5,)),          #           5x5
        ((cp, cin, 1, 1), (cp,)),          # branch 4: pool proj
    ]


# (stem) + inception1(16 -> 40) + inception2(40 -> 64) + head
_CLS_STRUCTURE = (
    [((16, 1, 3, 3), (16,))]
    + _inception_shapes(16, 8, 8, 16, 4, 8, 8)     # -> 8+16+8+8 = 40 ch
    + _inception_shapes(40, 16, 8, 24, 6, 12, 12)  # -> 16+24+12+12 = 64 ch
    + [((N_CLASSES, 64), (N_CLASSES,))]            # dense head
)


def classifier_param_shapes():
    """Ordered flat list of parameter shapes (W, b interleaved)."""
    out = []
    for w, b in _CLS_STRUCTURE:
        out.append(w)
        out.append(b)
    return out


def classifier_init(seed=0):
    """Ordered flat list of initialized parameters."""
    key = jax.random.PRNGKey(seed)
    params = []
    for shape in classifier_param_shapes():
        if len(shape) >= 2:
            key, sub = jax.random.split(key)
            params.append(_he(sub, shape))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def _inception_apply(x, p, i):
    """Apply one inception block; returns (output, next param index)."""
    b1 = _relu(_conv(x, p[i], p[i + 1]))
    b2 = _relu(_conv(x, p[i + 2], p[i + 3]))
    b2 = _relu(_conv(b2, p[i + 4], p[i + 5]))
    b3 = _relu(_conv(x, p[i + 6], p[i + 7]))
    b3 = _relu(_conv(b3, p[i + 8], p[i + 9]))
    pooled = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 3, 3), (1, 1, 1, 1), "SAME"
    )
    b4 = _relu(_conv(pooled, p[i + 10], p[i + 11]))
    return jnp.concatenate([b1, b2, b3, b4], axis=1), i + 12


def classifier_fwd(params, x):
    """Logits for a batch of TS frames x: (B, 1, 32, 32) -> (B, 10)."""
    p = list(params)
    h = _relu(_conv(x, p[0], p[1]))
    h = _maxpool(h)                      # 16x16
    h, i = _inception_apply(h, p, 2)
    h = _maxpool(h)                      # 8x8
    h, i = _inception_apply(h, p, i)
    gap = jnp.mean(h, axis=(2, 3))       # (B, 64)
    return gap @ p[i].T + p[i + 1]


def _softmax_ce(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def classifier_loss(params, x, y):
    return _softmax_ce(classifier_fwd(params, x), y)


def sgd_momentum_step(loss_fn, params, moms, lr, mu=0.9):
    """Generic SGD+momentum step over flat param lists."""
    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_moms = [mu * m + g for m, g in zip(moms, grads)]
    new_params = [p - lr * m for p, m in zip(params, new_moms)]
    return new_params, new_moms, loss


def classifier_train_step(params, moms, x, y, lr):
    """(params, moms, batch, labels, lr) -> (params', moms', loss)."""
    return sgd_momentum_step(lambda p: classifier_loss(p, x, y), params, moms, lr)


# ---------------------------------------------------------------------
# UNet-lite reconstruction model, input (B, 1, 64, 64)
# ---------------------------------------------------------------------

REC_INPUT = 64

_REC_STRUCTURE = [
    ((8, 1, 3, 3), (8,)),     # e1a
    ((8, 8, 3, 3), (8,)),     # e1b
    ((16, 8, 3, 3), (16,)),   # e2
    ((32, 16, 3, 3), (32,)),  # bottleneck
    ((16, 48, 3, 3), (16,)),  # d2 (cat: up(32) + e2(16))
    ((8, 24, 3, 3), (8,)),    # d1 (cat: up(16) + e1(8))
    ((1, 8, 1, 1), (1,)),     # head
]


def recon_param_shapes():
    out = []
    for w, b in _REC_STRUCTURE:
        out.append(w)
        out.append(b)
    return out


def recon_init(seed=0):
    key = jax.random.PRNGKey(seed + 1000)
    params = []
    for shape in recon_param_shapes():
        if len(shape) >= 2:
            key, sub = jax.random.split(key)
            params.append(_he(sub, shape))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def recon_fwd(params, x):
    """Reconstructed frame for TS input x: (B, 1, 64, 64) -> same shape."""
    p = list(params)
    e1 = _relu(_conv(x, p[0], p[1]))
    e1 = _relu(_conv(e1, p[2], p[3]))
    h = _maxpool(e1)                       # 32
    e2 = _relu(_conv(h, p[4], p[5]))
    h = _maxpool(e2)                       # 16
    h = _relu(_conv(h, p[6], p[7]))        # bottleneck 32ch
    h = _upsample2(h)                      # 32
    h = jnp.concatenate([h, e2], axis=1)   # 48
    h = _relu(_conv(h, p[8], p[9]))
    h = _upsample2(h)                      # 64
    h = jnp.concatenate([h, e1], axis=1)   # 24
    h = _relu(_conv(h, p[10], p[11]))
    return jax.nn.sigmoid(_conv(h, p[12], p[13]))


def recon_loss(params, x, y):
    return jnp.mean((recon_fwd(params, x) - y) ** 2)


def recon_train_step(params, moms, x, y, lr):
    return sgd_momentum_step(lambda p: recon_loss(p, x, y), params, moms, lr)


# ---------------------------------------------------------------------
# Jitted entry points for AOT lowering (fixed shapes)
# ---------------------------------------------------------------------

CLS_BATCH = 64
REC_BATCH = 8


@jax.jit
def ts_update_entry(v1, v2, mask, a1, a2, tau1, tau2, dt):
    return ts_update(v1, v2, mask, a1, a2, tau1, tau2, dt, use_pallas=True)


@jax.jit
def ts_frame_entry(v1, v2):
    return (ts_frame(v1, v2, use_pallas=True),)


@functools.partial(jax.jit, static_argnums=())
def stcf_count_entry(v, v_tw):
    return (stcf_count(v, v_tw, radius=3, use_pallas=True),)


@jax.jit
def classifier_fwd_entry(*args):
    params = list(args[:-1])
    return (classifier_fwd(params, args[-1]),)


@jax.jit
def classifier_train_entry(*args):
    n = len(classifier_param_shapes())
    params = list(args[:n])
    moms = list(args[n : 2 * n])
    x, y, lr = args[2 * n], args[2 * n + 1], args[2 * n + 2]
    new_p, new_m, loss = classifier_train_step(params, moms, x, y, lr)
    return tuple(new_p) + tuple(new_m) + (loss,)


@jax.jit
def recon_fwd_entry(*args):
    params = list(args[:-1])
    return (recon_fwd(params, args[-1]),)


@jax.jit
def recon_train_entry(*args):
    n = len(recon_param_shapes())
    params = list(args[:n])
    moms = list(args[n : 2 * n])
    x, y, lr = args[2 * n], args[2 * n + 1], args[2 * n + 2]
    new_p, new_m, loss = recon_train_step(params, moms, x, y, lr)
    return tuple(new_p) + tuple(new_m) + (loss,)
