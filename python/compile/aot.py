"""AOT lowering: JAX/Pallas graphs → HLO *text* artifacts for the Rust
PJRT runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out-dir, default ../artifacts):

  ts_update.hlo.txt       (v1,v2,mask,a1,a2,tau1,tau2,dt) -> (v1',v2')   [QVGA 240x320]
  ts_frame.hlo.txt        (v1,v2) -> (frame,)                            [QVGA]
  stcf_count.hlo.txt      (v,v_tw) -> (counts,)  r=3                     [QVGA]
  classifier_fwd.hlo.txt  (p0..p25, x[B,1,32,32]) -> (logits,)           [B=64]
  classifier_train.hlo.txt(p0..p25, m0..m25, x, y[B] i32, lr) -> (p'.., m'.., loss)
  recon_fwd.hlo.txt       (p0..p13, x[B,1,64,64]) -> (yhat,)             [B=8]
  recon_train.hlo.txt     (p.., m.., x, y, lr) -> (p'.., m'.., loss)
  classifier_params.npz / recon_params.npz   initial params (p000, p001, ...)
  manifest.txt            shapes + argument order for every artifact

Run via `make artifacts` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model

QVGA = (240, 320)


def to_hlo_text(jitted, *example_args) -> str:
    """Lower a jitted function and convert StableHLO -> XLA HLO text."""
    lowered = jitted.lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def pred(shape):
    return jax.ShapeDtypeStruct(shape, jnp.bool_)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_artifacts(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []

    def emit(name: str, jitted, *args):
        text = to_hlo_text(jitted, *args)
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        shapes = [f"{a.dtype}{list(a.shape)}" for a in args]
        manifest.append(f"{name}: args={shapes}")
        print(f"  wrote {name} ({len(text) / 1024:.0f} KiB)")

    # --- time-surface pipeline (QVGA) -----------------------------------
    plane = f32(QVGA)
    emit(
        "ts_update.hlo.txt",
        model.ts_update_entry,
        plane, plane, pred(QVGA), plane, plane, plane, plane, f32(()),
    )
    emit("ts_frame.hlo.txt", model.ts_frame_entry, plane, plane)
    emit("stcf_count.hlo.txt", model.stcf_count_entry, plane, f32(()))

    # --- classifier ------------------------------------------------------
    cls_shapes = model.classifier_param_shapes()
    cls_params = [f32(s) for s in cls_shapes]
    x_cls = f32((model.CLS_BATCH, 1, model.CLS_INPUT, model.CLS_INPUT))
    emit("classifier_fwd.hlo.txt", model.classifier_fwd_entry, *cls_params, x_cls)
    emit(
        "classifier_train.hlo.txt",
        model.classifier_train_entry,
        *cls_params, *cls_params, x_cls, i32((model.CLS_BATCH,)), f32(()),
    )

    # --- reconstruction --------------------------------------------------
    rec_shapes = model.recon_param_shapes()
    rec_params = [f32(s) for s in rec_shapes]
    x_rec = f32((model.REC_BATCH, 1, model.REC_INPUT, model.REC_INPUT))
    emit("recon_fwd.hlo.txt", model.recon_fwd_entry, *rec_params, x_rec)
    emit(
        "recon_train.hlo.txt",
        model.recon_train_entry,
        *rec_params, *rec_params, x_rec, x_rec, f32(()),
    )

    # --- initial parameters ----------------------------------------------
    for tag, init in (("classifier", model.classifier_init),
                      ("recon", model.recon_init)):
        params = init(seed=0)
        npz = {f"p{i:03d}": np.asarray(p) for i, p in enumerate(params)}
        path = os.path.join(out_dir, f"{tag}_params.npz")
        np.savez(path, **npz)
        manifest.append(f"{tag}_params.npz: {len(params)} arrays")
        print(f"  wrote {tag}_params.npz ({len(params)} arrays)")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    return {"artifacts": manifest}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None,
                    help="artifact directory (default: <repo>/artifacts)")
    # Back-compat with the scaffold Makefile's `--out path/to/model.hlo.txt`.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = args.out_dir
    if out_dir is None and args.out is not None:
        out_dir = os.path.dirname(os.path.abspath(args.out))
    if out_dir is None:
        out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    out_dir = os.path.abspath(out_dir)
    print(f"lowering artifacts to {out_dir}")
    build_artifacts(out_dir)
    # Marker file used by `make -q artifacts` freshness checks.
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write("ok\n")
    print("done")


if __name__ == "__main__":
    sys.exit(main())
