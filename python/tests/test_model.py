"""L2 model checks: shapes, gradient flow, and that a few train steps
reduce the loss on a tiny synthetic problem."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model

jax.config.update("jax_platform_name", "cpu")


def test_classifier_shapes():
    params = model.classifier_init(seed=0)
    assert len(params) == len(model.classifier_param_shapes())
    for p, s in zip(params, model.classifier_param_shapes()):
        assert p.shape == s
    x = jnp.zeros((4, 1, 32, 32), jnp.float32)
    logits = model.classifier_fwd(params, x)
    assert logits.shape == (4, model.N_CLASSES)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_classifier_learns_tiny_problem():
    # Two trivially separable "classes": bright left half vs bright right
    # half. A few SGD steps must reduce CE and reach high train accuracy.
    rng = np.random.default_rng(0)
    n = 64
    x = np.zeros((n, 1, 32, 32), np.float32)
    y = np.zeros((n,), np.int32)
    for i in range(n):
        c = i % 2
        y[i] = c
        if c == 0:
            x[i, 0, :, :16] = 1.0
        else:
            x[i, 0, :, 16:] = 1.0
        x[i] += rng.normal(0, 0.05, (1, 32, 32))
    params = model.classifier_init(seed=1)
    moms = [jnp.zeros_like(p) for p in params]
    loss0 = float(model.classifier_loss(params, x, y))
    step = jax.jit(model.classifier_train_step)
    loss = None
    for _ in range(30):
        params, moms, loss = step(params, moms, x, y, jnp.float32(0.05))
    assert float(loss) < loss0 * 0.5, f"loss {loss0} -> {float(loss)}"
    preds = np.argmax(np.asarray(model.classifier_fwd(params, x)), -1)
    acc = (preds == y).mean()
    assert acc > 0.9, f"train acc {acc}"


def test_recon_shapes_and_range():
    params = model.recon_init(seed=0)
    x = jnp.zeros((2, 1, 64, 64), jnp.float32)
    yhat = model.recon_fwd(params, x)
    assert yhat.shape == (2, 1, 64, 64)
    v = np.asarray(yhat)
    assert np.all((v >= 0.0) & (v <= 1.0)), "sigmoid output must be in [0,1]"


def test_recon_learns_identity_ish():
    # Reconstruct a smooth target from a correlated input: loss must drop.
    rng = np.random.default_rng(3)
    xs, ys = [], []
    for i in range(8):
        gx, gy = np.meshgrid(np.arange(64), np.arange(64))
        img = 0.5 + 0.4 * np.sin(gx / (4.0 + i) + i) * np.cos(gy / 5.0)
        ys.append(img.astype(np.float32)[None])
        xs.append((img + rng.normal(0, 0.1, img.shape)).astype(np.float32)[None])
    x = np.stack(xs); y = np.stack(ys)
    params = model.recon_init(seed=2)
    moms = [jnp.zeros_like(p) for p in params]
    loss0 = float(model.recon_loss(params, x, y))
    step = jax.jit(model.recon_train_step)
    loss = None
    for _ in range(40):
        params, moms, loss = step(params, moms, x, y, jnp.float32(0.2))
    assert float(loss) < loss0 * 0.6, f"loss {loss0} -> {float(loss)}"


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_init_is_deterministic(seed):
    a = model.classifier_init(seed)
    b = model.classifier_init(seed)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


def test_gradients_nonzero_everywhere():
    # Every parameter must receive gradient (no dead branches).
    params = model.classifier_init(seed=4)
    x = jnp.asarray(np.random.default_rng(1).uniform(0, 1, (8, 1, 32, 32)),
                    jnp.float32)
    y = jnp.asarray(np.arange(8) % model.N_CLASSES, jnp.int32)
    grads = jax.grad(lambda p: model.classifier_loss(p, x, y))(params)
    nonzero = [float(jnp.abs(g).max()) > 0 for g in grads]
    assert all(nonzero), f"dead params at {[i for i, z in enumerate(nonzero) if not z]}"
