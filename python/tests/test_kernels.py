"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and value ranges; assert_allclose everywhere.
This is the core correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import stcf as stcf_kernel
from compile.kernels import ts_decay as ts_kernel

jax.config.update("jax_platform_name", "cpu")


def _planes(rng, h, w):
    v1 = rng.uniform(0.0, 0.2, (h, w)).astype(np.float32)
    v2 = rng.uniform(0.0, 1.1, (h, w)).astype(np.float32)
    mask = rng.uniform(size=(h, w)) < 0.1
    a1 = rng.uniform(0.10, 0.20, (h, w)).astype(np.float32)
    a2 = rng.uniform(0.95, 1.10, (h, w)).astype(np.float32)
    tau1 = rng.uniform(4e-3, 8e-3, (h, w)).astype(np.float32)
    tau2 = rng.uniform(20e-3, 28e-3, (h, w)).astype(np.float32)
    return v1, v2, mask, a1, a2, tau1, tau2


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(2, 48),
    w=st.integers(2, 48),
    dt_ms=st.floats(0.01, 50.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_ts_update_matches_ref(h, w, dt_ms, seed):
    rng = np.random.default_rng(seed)
    v1, v2, mask, a1, a2, tau1, tau2 = _planes(rng, h, w)
    dt = np.float32(dt_ms * 1e-3)
    got1, got2 = ts_kernel.ts_update(v1, v2, mask, a1, a2, tau1, tau2, dt)
    want1, want2 = ref.ts_update_ref(v1, v2, mask, a1, a2, tau1, tau2, dt)
    np.testing.assert_allclose(got1, want1, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(got2, want2, rtol=1e-6, atol=1e-7)


def test_ts_update_qvga_block_path():
    # Exercise the tiled (256,256)-block path with a power-of-two friendly
    # shape and the exact QVGA fallback shape.
    for (h, w) in [(256, 512), (240, 320)]:
        rng = np.random.default_rng(7)
        v1, v2, mask, a1, a2, tau1, tau2 = _planes(rng, h, w)
        dt = np.float32(1e-3)
        got1, got2 = ts_kernel.ts_update(v1, v2, mask, a1, a2, tau1, tau2, dt)
        want1, want2 = ref.ts_update_ref(v1, v2, mask, a1, a2, tau1, tau2, dt)
        np.testing.assert_allclose(got1, want1, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(got2, want2, rtol=1e-6, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(8, 40),
    w=st.integers(8, 40),
    radius=st.integers(1, 4),
    v_tw=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_patch_count_matches_ref(h, w, radius, v_tw, seed):
    rng = np.random.default_rng(seed)
    v = rng.uniform(0.0, 1.2, (h, w)).astype(np.float32)
    got = stcf_kernel.patch_count(v, np.float32(v_tw), radius)
    want = ref.patch_count_ref(v, np.float32(v_tw), radius)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_patch_count_hand_case():
    # Single hot pixel in the middle: every cell within r gets count 1,
    # except the hot pixel itself (center excluded).
    v = np.zeros((9, 9), np.float32)
    v[4, 4] = 1.0
    out = np.asarray(stcf_kernel.patch_count(v, np.float32(0.5), 2))
    assert out[4, 4] == 0.0
    assert out[3, 4] == 1.0
    assert out[6, 6] == 1.0
    assert out[0, 0] == 0.0
    assert out.sum() == 24.0  # 5x5 patch minus center


@settings(max_examples=10, deadline=None)
@given(h=st.integers(2, 32), w=st.integers(2, 32), seed=st.integers(0, 2**31 - 1))
def test_ts_frame_matches_ref(h, w, seed):
    rng = np.random.default_rng(seed)
    v1 = rng.uniform(0.0, 0.3, (h, w)).astype(np.float32)
    v2 = rng.uniform(0.0, 1.2, (h, w)).astype(np.float32)
    got = ts_kernel.ts_frame(v1, v2, 1.2)
    want = ref.ts_frame_ref(v1, v2, 1.2)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
    assert np.all(np.asarray(got) <= 1.0)
    assert np.all(np.asarray(got) >= 0.0)


def test_ts_update_write_sets_amplitudes():
    # A masked write must reset to (A1, A2) exactly, regardless of decay.
    v1 = np.full((4, 4), 0.01, np.float32)
    v2 = np.full((4, 4), 0.02, np.float32)
    mask = np.zeros((4, 4), bool); mask[1, 2] = True
    a1 = np.full((4, 4), 0.153, np.float32)
    a2 = np.full((4, 4), 1.047, np.float32)
    tau = np.full((4, 4), 0.02, np.float32)
    o1, o2 = ts_kernel.ts_update(v1, v2, mask, a1, a2, tau, tau, np.float32(1.0))
    assert np.isclose(o1[1, 2], 0.153)
    assert np.isclose(o2[1, 2], 1.047)
    # Unwritten pixels decayed by e^{-50} ~ 0.
    assert o1[0, 0] < 1e-8


def test_decay_sequence_matches_double_exp():
    # Stepping the state N times with dt must equal the closed-form
    # double exponential at N*dt (memorylessness of the 2-component state).
    h = w = 4
    a1 = np.full((h, w), 0.153, np.float32)
    a2 = np.full((h, w), 1.047, np.float32)
    tau1 = np.full((h, w), 6.14e-3, np.float32)
    tau2 = np.full((h, w), 23.9e-3, np.float32)
    mask_on = np.ones((h, w), bool)
    mask_off = np.zeros((h, w), bool)
    v1, v2 = ts_kernel.ts_update(a1 * 0, a2 * 0, mask_on, a1, a2, tau1, tau2,
                                 np.float32(0.0))
    dt = np.float32(2e-3)
    for _ in range(10):
        v1, v2 = ts_kernel.ts_update(v1, v2, mask_off, a1, a2, tau1, tau2, dt)
    t = 10 * 2e-3
    expect = 0.153 * np.exp(-t / 6.14e-3) + 1.047 * np.exp(-t / 23.9e-3)
    np.testing.assert_allclose(np.asarray(v1 + v2), expect, rtol=1e-4)
