"""AOT emission checks: artifacts lower, parse as HLO text, and carry the
documented argument counts."""

import os
import tempfile

import numpy as np

from compile import aot, model


def test_build_artifacts_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        aot.build_artifacts(d)
        names = set(os.listdir(d))
        expected = {
            "ts_update.hlo.txt", "ts_frame.hlo.txt", "stcf_count.hlo.txt",
            "classifier_fwd.hlo.txt", "classifier_train.hlo.txt",
            "recon_fwd.hlo.txt", "recon_train.hlo.txt",
            "classifier_params.npz", "recon_params.npz", "manifest.txt",
        }
        assert expected <= names, expected - names
        # HLO text sanity: module header and an ENTRY computation.
        for f in [n for n in expected if n.endswith(".hlo.txt")]:
            text = open(os.path.join(d, f)).read()
            assert text.startswith("HloModule"), f
            assert "ENTRY" in text, f
        # Param archives round-trip with the documented count and order.
        cls = np.load(os.path.join(d, "classifier_params.npz"))
        assert len(cls.files) == len(model.classifier_param_shapes())
        assert sorted(cls.files) == cls.files  # p000.. ordering is sortable
        for i, s in enumerate(model.classifier_param_shapes()):
            assert cls[f"p{i:03d}"].shape == s


def test_train_artifact_param_counts():
    # classifier_train: 2P + 3 inputs, 2P + 1 outputs (documented contract
    # the Rust runtime relies on).
    p = len(model.classifier_param_shapes())
    assert p == 28
    r = len(model.recon_param_shapes())
    assert r == 14
